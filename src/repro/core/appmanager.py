"""AppManager: the master component of the toolkit (paper §II-B.2/3).

Responsibilities, mirroring the paper:

* holds the application description and the authoritative state table,
* creates all queues, spawns the Synchronizer, instantiates WFProcessor and
  ExecManager,
* supervises component threads (restarting any that die — failure model),
* supervises the RTS through the ExecManager heartbeat (restart + resubmit),
* journals every transition so a full toolkit failure can resume "up to the
  latest successful transaction" (``resume=True`` skips completed tasks by
  name),
* exposes the overhead decomposition the paper measures (setup / management /
  tear-down / RTS / staging / execution).

Beyond the paper (framework requirements at 10³+ nodes): elastic pilot
resizing, straggler speculation (see ExecManager), pluggable RTS factories.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Union

from .. import telemetry as tel
from . import states as st
from .broker import Broker
from .exceptions import EnTKError, ValueError_
from .journal import Journal
from .policies import RetryPolicy
from .profiler import (ENTK_SETUP, ENTK_TEARDOWN, Profiler)
from .pst import Pipeline, WorkflowIndex
from .execmanager import ExecManager
from .state_service import StateService
from .synchronizer import Synchronizer
from .wfprocessor import WFProcessor
from ..rts.base import RTS, ResourceDescription
from ..rts.federation import FederatedRTS, MemberSpec
from ..rts.local import LocalRTS


class AppManager:
    """Programmatic entry point.

    Typical use::

        amgr = AppManager(resources=ResourceDescription(slots=8))
        amgr.workflow = [pipeline1, pipeline2]
        amgr.run()

    ``rts_factory`` defaults to :class:`LocalRTS`. ``journal_path`` enables
    durable transactions and resume.

    **Multi-resource (federated) runs**: pass a *list* of resource
    descriptions — one per pilot — and, optionally, a matching list of RTS
    factories (a single factory is reused for every member). The AppManager
    then drives a :class:`~repro.rts.federation.FederatedRTS` over the whole
    fleet: one workflow spans every pilot, tasks optionally pin to a member
    through ``Task.backend`` (member names come from
    ``description.extra['name']``, defaulting to ``member<i>``), and a pilot
    that dies mid-run fails over onto the surviving members.
    ``member_restarts`` budgets rebuilding a dead member from its factory.
    """

    def __init__(
        self,
        resources: Optional[Union[ResourceDescription,
                                  List[ResourceDescription]]] = None,
        rts_factory: Optional[Union[Callable[[], RTS],
                                    List[Callable[[], RTS]]]] = None,
        journal_path: Optional[str] = None,
        strict_transactions: bool = False,
        on_task_failure: str = "continue",
        heartbeat_interval: float = 0.5,
        max_rts_restarts: int = 3,
        straggler_factor: float = 0.0,
        straggler_min_seconds: float = 1.0,
        speculation_min_samples: int = 64,
        retry_policy: Optional["RetryPolicy"] = None,
        component_supervision: bool = True,
        flush_every: int = 32,
        fsync_critical: bool = True,
        member_restarts: int = 0,
    ) -> None:
        if isinstance(resources, (list, tuple)):
            specs = self._member_specs(list(resources), rts_factory)
            self.resources = ResourceDescription(
                slots=sum(rd.slots for rd in resources),
                platform="federated")
            self.rts_factory = lambda: FederatedRTS(
                specs, heartbeat_interval=heartbeat_interval,
                member_restarts=member_restarts)
        else:
            if isinstance(rts_factory, (list, tuple)):
                raise ValueError_(
                    "a list of rts factories requires a matching list of "
                    "resource descriptions")
            rd = resources or ResourceDescription(slots=4)
            # own copy: the toolkit records granted-not-requested capacity
            # into its description (acquire/resize), and that bookkeeping
            # must never write through into the caller's object
            self.resources = dataclasses.replace(rd, extra=dict(rd.extra))
            self.rts_factory = rts_factory or LocalRTS
        self.journal_path = journal_path
        self.strict_transactions = strict_transactions
        self.on_task_failure = on_task_failure
        self.heartbeat_interval = heartbeat_interval
        self.max_rts_restarts = max_rts_restarts
        self.straggler_factor = straggler_factor
        self.straggler_min_seconds = straggler_min_seconds
        self.speculation_min_samples = speculation_min_samples
        self.retry_policy = retry_policy
        self.component_supervision = component_supervision
        self.flush_every = flush_every
        self.fsync_critical = fsync_critical

        self._workflow: List[Pipeline] = []
        self.prof = Profiler()
        self.state_table: Dict[str, str] = {}
        # O(1) uid -> object routing shared by WFProcessor and ExecManager
        # (replaces the bare task_index dict + linear pipeline/stage scans)
        self.index = WorkflowIndex()

        self.broker: Optional[Broker] = None
        self.journal: Optional[Journal] = None
        self.svc: Optional[StateService] = None
        self.sync: Optional[Synchronizer] = None
        self.wfp: Optional[WFProcessor] = None
        self.emgr: Optional[ExecManager] = None
        self._supervisor: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.component_restarts = 0
        self._terminated = False

    @staticmethod
    def _member_specs(
        rds: List[ResourceDescription],
        rts_factory: Optional[Union[Callable[[], RTS],
                                    List[Callable[[], RTS]]]],
    ) -> List[MemberSpec]:
        if not rds:
            raise ValueError_("multi-resource run requires >= 1 description")
        if isinstance(rts_factory, (list, tuple)):
            factories = list(rts_factory)
            if len(factories) != len(rds):
                raise ValueError_(
                    f"{len(rds)} resource descriptions but "
                    f"{len(factories)} rts factories")
        else:
            factories = [rts_factory or LocalRTS] * len(rds)
        specs = []
        for i, (rd, factory) in enumerate(zip(rds, factories)):
            name = str(rd.extra.get("name", f"member{i}"))
            specs.append(MemberSpec(name=name, factory=factory, resources=rd))
        names = [s.name for s in specs]
        if len(names) != len(set(names)):
            # fail fast at construction — the FederatedRTS factory would
            # only surface this at resource-acquisition time
            raise ValueError_(f"duplicate federation member names: {names}")
        return specs

    # -- workflow handling -----------------------------------------------------#

    @property
    def workflow(self) -> List[Pipeline]:
        return self._workflow

    @workflow.setter
    def workflow(self, value) -> None:
        """Assign the application description, validating it *now*.

        Mis-described workflows used to surface deep inside the run (a
        non-Pipeline entry crashed the Enqueue thread; duplicate names broke
        resume keying and the declarative result store silently). Accepts a
        single Pipeline, a list of Pipelines, or anything iterable over
        Pipelines (e.g. an ``api.compile()`` result).
        """
        if isinstance(value, Pipeline):
            value = [value]
        pipelines = list(value)
        for entry in pipelines:
            if not isinstance(entry, Pipeline):
                raise ValueError_(
                    f"workflow entries must be Pipeline, got "
                    f"{type(entry).__name__}: {entry!r} — wrap Stages/Tasks "
                    f"in a Pipeline (or use repro.api and compile())")
        pnames = [p.name for p in pipelines]
        if len(pnames) != len(set(pnames)):
            dupes = sorted({n for n in pnames if pnames.count(n) > 1})
            raise ValueError_(
                f"duplicate pipeline names in workflow: {dupes} — pipeline "
                f"names must be unique (they key journal replay and the "
                f"state table)")
        tnames = [t.name for p in pipelines for s in p.stages
                  for t in s.tasks]
        if len(tnames) != len(set(tnames)):
            seen, dupes = set(), set()
            for n in tnames:
                (dupes if n in seen else seen).add(n)
            raise ValueError_(
                f"duplicate task names in workflow: {sorted(dupes)[:5]} — "
                f"task names must be unique across the workflow (resume and "
                f"result routing are keyed on them)")
        self._workflow = pipelines

    def _validate(self, resume: bool) -> None:
        if not self.workflow:
            raise ValueError_("workflow is empty")
        names = [t.name for p in self.workflow for s in p.stages
                 for t in s.tasks]
        if (resume or self.journal_path) and len(names) != len(set(names)):
            raise ValueError_(
                "resumable workflows require unique task names")
        for p in self.workflow:
            if not p.stages:
                raise ValueError_(f"pipeline {p.uid} has no stages")
            for s in p.stages:
                if not s.tasks:
                    raise ValueError_(f"stage {s.uid} has no tasks")

    def _index_tasks(self) -> None:
        for p in self.workflow:
            self.index.add_pipeline(p)

    # -- main entry -------------------------------------------------------------#

    def run(self, resume: bool = False, timeout: float = 3600.0) -> Dict[str, float]:
        """Execute the workflow to completion; returns the overhead report.

        ``resume=True`` replays the journal at ``journal_path`` and skips
        tasks whose last journaled state was DONE.
        """
        # ---- setup (profiled: EnTK Setup Overhead) --------------------------- #
        self.prof.begin(ENTK_SETUP)
        setup_span = tel.span("appmanager.setup", "am",
                              pipelines=len(self.workflow), resume=resume)
        self._validate(resume)
        resumed_done = set()
        resumed_retries: Dict[str, int] = {}
        resumed_results: Dict[str, object] = {}
        result_omitted: set = set()
        if resume and self.journal_path and os.path.exists(self.journal_path):
            replay = Journal.replay(self.journal_path)
            for (kind, name), state in replay["state"].items():
                if kind == "task" and state == st.DONE:
                    resumed_done.add(name)
            resumed_retries = dict(replay["retries"])
            resumed_results = dict(replay["results"])
            result_omitted = set(replay["result_omitted"])
        self._index_tasks()
        for p in self.workflow:
            for s in p.stages:
                for t in s.tasks:
                    if t.name in resumed_retries:
                        t.retries = min(t.max_retries,
                                        resumed_retries[t.name])
        self.broker = Broker()
        self.journal = Journal(self.journal_path,
                               flush_every=self.flush_every,
                               fsync_critical=self.fsync_critical)
        self.journal.session("resume" if resume else "start",
                             pipelines=len(self.workflow))
        self.svc = StateService(self.broker, strict=self.strict_transactions,
                                durable=self.journal.enabled)
        self.sync = Synchronizer(self.broker, self.journal, self.state_table)
        self.sync.start()
        self.wfp = WFProcessor(
            self.broker, self.svc, self.prof, self.workflow, self.index,
            on_task_failure=self.on_task_failure, resumed_done=resumed_done,
            # results restore at scheduling time (covers stages appended at
            # runtime by adaptive rounds, not just the static prefix)
            resumed_results=resumed_results, result_omitted=result_omitted,
            # sidecar for results that journal as spill records (fused
            # device arrays) — only meaningful with a write-ahead journal
            spill_dir=(f"{self.journal_path}.spill"
                       if self.journal_path else None),
            retry_policy=self.retry_policy)
        self.emgr = ExecManager(
            self.broker, self.svc, self.prof, self.rts_factory,
            self.resources, self.index,
            heartbeat_interval=self.heartbeat_interval,
            max_rts_restarts=self.max_rts_restarts,
            straggler_factor=self.straggler_factor,
            straggler_min_seconds=self.straggler_min_seconds,
            speculation_min_samples=self.speculation_min_samples)
        setup_span.end()
        self.prof.end(ENTK_SETUP)

        # ---- resources + execution ---------------------------------------- #
        self.emgr.acquire_resources()
        # superstage scheduling is only sound against an RTS that composes
        # chains itself (it receives downstream links before their inputs
        # are routed and orders them internally); everywhere else stage
        # ordering keeps gating submissions
        chain_ok = getattr(self.emgr.rts, "supports_chain_fusion", None)
        try:
            self.wfp.chain_scheduling = bool(chain_ok and chain_ok())
        except Exception:  # noqa: BLE001 - a dying RTS answers like "no"
            self.wfp.chain_scheduling = False
        self.wfp.start()
        self.emgr.start()
        if self.component_supervision:
            self._stop.clear()
            self._supervisor = threading.Thread(
                target=self._supervise, daemon=True, name="am-supervisor")
            self._supervisor.start()

        try:
            deadline = time.monotonic() + timeout
            # event-driven wait: the WFProcessor sets done_event when the
            # last pipeline finalizes; the short timeout only bounds how
            # quickly errors/timeout are noticed, it does no scheduling work
            while not self.wfp.done_event.wait(timeout=0.05):
                if time.monotonic() > deadline:
                    raise EnTKError(f"workflow timed out after {timeout}s")
                if (self.emgr.component_errors
                        and "restart budget exhausted"
                        in self.emgr.component_errors[-1]):
                    raise EnTKError("RTS restart budget exhausted")
        finally:
            self._terminate()
        return self.prof.totals()

    # -- serving mode (persistent multi-tenant daemon) -----------------------#

    def start_service(self, journal: Optional[Journal] = None) -> None:
        """Bring up the full component stack with no workflow attached.

        The serving layer (``repro.serve``) submits workflows afterwards
        through :meth:`submit_pipelines`; the components drain-and-wait
        instead of drain-and-exit. ``journal`` accepts a Journal-compatible
        router (the service's :class:`~repro.serve.journal.TenantJournals`)
        so transitions land in per-tenant write-ahead files.
        """
        if self.broker is not None:
            raise EnTKError("service already started")
        self.prof.begin(ENTK_SETUP)
        setup_span = tel.span("appmanager.setup", "am", service=True)
        self.broker = Broker()
        self.journal = (journal if journal is not None
                        else Journal(self.journal_path,
                                     flush_every=self.flush_every,
                                     fsync_critical=self.fsync_critical))
        self.journal.session("start", service=True)
        self.svc = StateService(self.broker, strict=self.strict_transactions,
                                durable=self.journal.enabled)
        self.sync = Synchronizer(self.broker, self.journal, self.state_table)
        self.sync.start()
        self.wfp = WFProcessor(
            self.broker, self.svc, self.prof, self._workflow, self.index,
            on_task_failure=self.on_task_failure,
            spill_dir=(f"{self.journal_path}.spill"
                       if self.journal_path else None),
            retry_policy=self.retry_policy)
        self.emgr = ExecManager(
            self.broker, self.svc, self.prof, self.rts_factory,
            self.resources, self.index,
            heartbeat_interval=self.heartbeat_interval,
            max_rts_restarts=self.max_rts_restarts,
            straggler_factor=self.straggler_factor,
            straggler_min_seconds=self.straggler_min_seconds,
            speculation_min_samples=self.speculation_min_samples)
        setup_span.end()
        self.prof.end(ENTK_SETUP)
        self.emgr.acquire_resources()
        chain_ok = getattr(self.emgr.rts, "supports_chain_fusion", None)
        try:
            self.wfp.chain_scheduling = bool(chain_ok and chain_ok())
        except Exception:  # noqa: BLE001 - a dying RTS answers like "no"
            self.wfp.chain_scheduling = False
        self.wfp.start()
        self.emgr.start()
        if self.component_supervision:
            self._stop.clear()
            self._supervisor = threading.Thread(
                target=self._supervise, daemon=True, name="am-supervisor")
            self._supervisor.start()

    def submit_pipelines(
        self,
        pipelines: List[Pipeline],
        ns: Optional[str] = None,
        resumed_done: Optional[set] = None,
        resumed_results: Optional[Dict[str, object]] = None,
        result_omitted: Optional[set] = None,
        resumed_retries: Optional[Dict[str, int]] = None,
        spill_dir: Optional[str] = None,
    ) -> None:
        """Admit a workflow into the running service.

        Bypasses the ``workflow`` setter's cross-workflow task-name
        uniqueness check deliberately: each submission's names are unique
        within its own compile namespace (``_Ctx.claim``) and all routing —
        results, journals, resume — is keyed ``(namespace, name)``.
        """
        if self.wfp is None:
            raise EnTKError("start_service() before submit_pipelines()")
        for entry in pipelines:
            if not isinstance(entry, Pipeline):
                raise ValueError_(
                    f"submit_pipelines expects Pipeline, got "
                    f"{type(entry).__name__}")
        if resumed_retries:
            for p in pipelines:
                for s in p.stages:
                    for t in s.tasks:
                        if t.name in resumed_retries:
                            t.retries = min(t.max_retries,
                                            resumed_retries[t.name])
        if ns is not None and (resumed_done or resumed_results
                               or result_omitted or spill_dir):
            self.wfp.add_resumed_namespace(
                ns, resumed_done or set(), resumed_results or {},
                result_omitted or set(), spill_dir=spill_dir)
        for p in pipelines:
            self.index.add_pipeline(p)
        self._workflow.extend(pipelines)
        self.wfp.add_pipelines(pipelines)

    def cancel_pipelines(self, pipelines: List[Pipeline]) -> None:
        """Cancel one submission's pipelines without touching the others.

        Mirrors :meth:`cancel`'s locking, then finalizes each pipeline to
        CANCELED itself (the RTS drops queued/held members without emitting
        completions, so the normal closure chain would never fire)."""
        import contextlib

        uids = [t.uid for p in pipelines for s in p.stages for t in s.tasks
                if not t.is_final]
        if self.emgr is not None and self.emgr.rts is not None and uids:
            self.emgr.rts.cancel(uids)
        emgr_lock = (self.emgr._lock if self.emgr is not None
                     else contextlib.nullcontext())
        for p in pipelines:
            canceled_now = False
            with p.lock, emgr_lock:
                if p.is_final:
                    continue
                for s in p.stages:
                    for t in s.tasks:
                        if not t.is_final and self.svc is not None:
                            try:
                                self.svc.advance(t, st.CANCELED)
                            except Exception:  # noqa: BLE001
                                pass
                    if not s.is_final and self.svc is not None:
                        try:
                            self.svc.advance(s, st.STAGE_CANCELED)
                        except Exception:  # noqa: BLE001
                            pass
                if self.svc is not None:
                    try:
                        self.svc.advance(p, st.PIPELINE_CANCELED)
                        canceled_now = True
                    except Exception:  # noqa: BLE001
                        pass
            if canceled_now and self.wfp is not None:
                self.wfp.note_pipeline_closed(p)
        if self.emgr is not None and uids:
            # canceled members the RTS dropped without a completion (queued
            # or parked in a batching hold) would otherwise stay in Emgr
            # custody forever and block its quiescence accounting; a member
            # actually mid-execution still completes, and its late callback
            # is a harmless duplicate after this purge
            with self.emgr._lock:
                for u in uids:
                    self.emgr._submitted.pop(u, None)

    def stop_service(self) -> Dict[str, float]:
        """Tear the service down; returns the overhead report."""
        self._terminate()
        return self.prof.totals()

    def cancel(self) -> None:
        """Cancel all outstanding work and finalize.

        Takes each pipeline's lock (serializing against the WFProcessor's
        completion chains) AND the ExecManager's lock (serializing against
        the submission chain, which runs outside pipeline locks) so the
        CANCELED transition can neither interleave with nor be overwritten
        by a concurrent multi-hop advance on the same task."""
        import contextlib

        if self.emgr is not None and self.emgr.rts is not None:
            self.emgr.rts.cancel(self.emgr.rts.in_flight())
        emgr_lock = (self.emgr._lock if self.emgr is not None
                     else contextlib.nullcontext())
        for p in self.workflow:
            with p.lock, emgr_lock:
                for s in p.stages:
                    for t in s.tasks:
                        if not t.is_final and self.svc is not None:
                            try:
                                self.svc.advance(t, st.CANCELED)
                            except Exception:  # noqa: BLE001
                                pass

    # -- teardown ------------------------------------------------------------#

    def _terminate(self) -> None:
        if self._terminated:
            return
        self._terminated = True
        self._stop.set()
        if self._supervisor is not None:
            self._supervisor.join(timeout=2.0)
        # RTS teardown is profiled separately inside ExecManager.stop
        if self.emgr is not None:
            self.emgr.stop()
        self.prof.begin(ENTK_TEARDOWN)
        with tel.span("appmanager.teardown", "am"):
            if self.wfp is not None:
                self.wfp.stop()
            if self.sync is not None:
                self.sync.stop()
            if self.journal is not None:
                self.journal.session("end")
                self.journal.close()
            if self.broker is not None:
                self.broker.close()
        if self.journal_path and tel.enabled():
            # journal-adjacent metrics snapshot: <journal>.telemetry.jsonl
            # lands next to the WAL so a postmortem reads both side by side
            try:
                tel.export_jsonl(f"{self.journal_path}.telemetry.jsonl")
            except OSError:
                pass
        self.prof.end(ENTK_TEARDOWN)

    # -- component supervision ---------------------------------------------------#

    def _supervise(self) -> None:
        """Restart dead component threads (EnTK-component failure model)."""
        while not self._stop.is_set():
            # interruptible wait: _terminate must not block on a sleeping
            # supervisor for a join-timeout at every shutdown
            self._stop.wait(self.heartbeat_interval)
            if self._stop.is_set():
                return
            try:
                if self.sync is not None and not self.sync.is_alive():
                    self.sync.crash_hook = None
                    self.broker.requeue_unacked("states")
                    self.sync.start()
                    self.component_restarts += 1
                if self.wfp is not None:
                    alive = self.wfp.threads_alive()
                    if not alive["enqueue"]:
                        self.wfp.enqueue_crash_hook = None
                        self.broker.requeue_unacked("schedule")
                        self.wfp.start_enqueue()
                        self.component_restarts += 1
                    if not alive["dequeue"]:
                        self.wfp.dequeue_crash_hook = None
                        self.broker.requeue_unacked("done")
                        self.wfp.start_dequeue()
                        self.component_restarts += 1
                if self.emgr is not None:
                    ealive = self.emgr.threads_alive()
                    if not ealive["emgr"]:
                        self.emgr.emgr_crash_hook = None
                        self.broker.requeue_unacked("pending")
                        self.emgr.start_emgr()
                        self.component_restarts += 1
                    if not ealive["heartbeat"]:
                        self.emgr.start_heartbeat()
                        self.component_restarts += 1
                    if not ealive.get("watchdog", True):
                        self.emgr.start_watchdog()
                        self.component_restarts += 1
            except Exception:  # noqa: BLE001 - supervisor must survive anything
                pass

    # -- convenience ------------------------------------------------------------#

    def states_of(self, names: List[str]) -> Dict[str, str]:
        return {n: self.state_table.get(f"task:{n}", "UNKNOWN") for n in names}

    @property
    def all_done(self) -> bool:
        return all(
            t.state == st.DONE
            for p in self.workflow for s in p.stages for t in s.tasks)
