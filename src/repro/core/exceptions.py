"""Exception hierarchy for the EnTK-JAX core.

Mirrors the error taxonomy of the paper's failure model (§II-B.4): errors are
classified by their source — user/API error, EnTK component failure, RTS
failure, or task failure — because each class triggers a different recovery
path (reject, restart component, restart RTS, resubmit task).
"""

from __future__ import annotations


class EnTKError(Exception):
    """Base class for all toolkit errors."""


class TypeError_(EnTKError):
    """A PST object or argument had the wrong type (API-level user error)."""


class ValueError_(EnTKError):
    """A PST object or argument had an invalid value (API-level user error)."""


class MissingError(EnTKError):
    """A required attribute was missing from a PST description."""


class StateTransitionError(EnTKError):
    """An illegal state transition was attempted.

    All transitions are validated against the transition tables in
    :mod:`repro.core.states`; violating them indicates a toolkit bug, never a
    user error, so this is raised eagerly rather than recovered from.
    """

    def __init__(self, obj: str, from_state: str, to_state: str) -> None:
        super().__init__(
            f"illegal state transition for {obj}: {from_state!r} -> {to_state!r}"
        )
        self.obj = obj
        self.from_state = from_state
        self.to_state = to_state


class ComponentFailure(EnTKError):
    """An EnTK component (thread) died; AppManager may restart it."""


class RTSFailure(EnTKError):
    """The runtime system failed or became unresponsive.

    Per the paper's failure model the RTS is a black box: on this error the
    AppManager tears the RTS down, purges leftovers, starts a fresh instance
    and resubmits the tasks that were in flight.
    """


class TaskFailure(EnTKError):
    """A task executable failed; subject to the task's retry budget."""


class ResourceError(EnTKError):
    """Resource acquisition failed (pilot could not be started/resized)."""


class JournalCorruption(EnTKError):
    """The write-ahead journal could not be replayed."""
