"""Process-global output store: task results routed to their consumers.

The declarative API (``repro.api``) compiles data-flow edges — a task
consuming another task's *return value* — down to PST tasks whose kwargs
carry ``{"__future__": <producer name>}`` placeholders. Somebody has to hold
the produced values between the producer's completion and the consumer's
execution; that is this store.

* **Writer**: the WFProcessor's Dequeue routes ``task.result`` here when a
  task tagged with a workflow namespace (``task.tags["_wf_ns"]``) reaches
  DONE — before the stage-closure accounting that makes the consumer's stage
  schedulable, so a consumer can never execute before its inputs are
  readable. Adaptive combinators (``repeat_until``/``branch``) additionally
  write their aggregate values from their ``post_exec`` hooks.
* **Reader**: the API trampoline (``repro.api.runtime``) resolves
  placeholders at execution time, RTS-side; ``Future.result()`` reads the
  same keys after the run.
* **Resume**: the AppManager preloads replayed journal results for
  resumed-DONE tasks before the workflow starts, so consumers of tasks
  completed in a previous session still find their inputs.

Keys are ``(namespace, task name)``: the namespace is minted per
``api.compile()`` call, so concurrent workflows in one process (tests, the
federation benchmarks) never collide even when task names repeat.

The store is deliberately process-global and unbounded for the lifetime of a
namespace — values stay readable after the run for ``Future.result()``.
Long-lived processes that run many workflows should call
:meth:`ResultStore.clear_namespace` (``api`` does this in
``Compiled.close()``).
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Tuple

from .exceptions import MissingError

_MISSING = object()


# --------------------------------------------------------------------------- #
# Journal-value codecs
# --------------------------------------------------------------------------- #
# Rich result objects (e.g. the fusion engine's device-resident ArrayResult)
# cannot ride a DONE record as JSON. Instead they journal a small tagged dict
# ({"__codec__": <tag>, ...}) produced by the object's ``to_journal`` hook,
# and replay turns the dict back into the live object through a decoder
# registered here. The core stays ignorant of any concrete codec — higher
# layers register theirs at import time (see repro.fusion.handles).

_CODEC_KEY = "__codec__"
_CODECS: Dict[str, Callable[[Dict[str, Any]], Any]] = {}
_SPILLERS: list = []
_codec_lock = threading.Lock()


def register_result_codec(tag: str,
                          decode: Callable[[Dict[str, Any]], Any]) -> None:
    """Register ``decode`` for journal records tagged ``tag``."""
    with _codec_lock:
        _CODECS[tag] = decode


def register_result_spiller(
        spill: Callable[[Any, str], "Dict[str, Any] | None"]) -> None:
    """Register ``spill(value, spill_dir) -> record|None``: a last chance
    to journal a value that neither carries a ``to_journal`` hook nor
    JSON-round-trips. Returning a tagged record (decodable through a
    registered codec) journals it; ``None`` passes to the next spiller
    (and ultimately to ``result_omitted``)."""
    with _codec_lock:
        _SPILLERS.append(spill)


def spill_journal_value(value: Any, spill_dir: Any) -> Any:
    """Offer ``value`` to the registered spillers; record dict or None."""
    if not spill_dir:
        return None
    with _codec_lock:
        spillers = list(_SPILLERS)
    for spill in spillers:
        try:
            record = spill(value, spill_dir)
        except Exception:  # noqa: BLE001 - a failed spill is just omitted
            record = None
        if record is not None:
            return record
    return None


def decode_journal_value(value: Any) -> Any:
    """Decode a journal-replayed result value.

    Plain values pass through. Tagged dicts dispatch to their codec; an
    unknown tag or a failing decoder raises :class:`MissingError`, which the
    resume path answers by re-running the producer (the same contract as
    ``result_omitted``).
    """
    if isinstance(value, dict) and _CODEC_KEY in value:
        with _codec_lock:
            decode = _CODECS.get(value[_CODEC_KEY])
        if decode is None:
            raise MissingError(
                f"no result codec registered for journal tag "
                f"{value[_CODEC_KEY]!r} — import the producing subsystem "
                f"before resuming")
        return decode(value)
    return value


class ResultStore:
    """Thread-safe ``(namespace, name) -> value`` map."""

    def __init__(self) -> None:
        self._data: Dict[Tuple[str, str], Any] = {}
        self._lock = threading.Lock()

    def put(self, ns: str, name: str, value: Any) -> None:
        with self._lock:
            self._data[(ns, name)] = value

    def get(self, ns: str, name: str, default: Any = _MISSING) -> Any:
        with self._lock:
            value = self._data.get((ns, name), _MISSING)
        if value is _MISSING:
            if default is _MISSING:
                raise MissingError(
                    f"no result for task {name!r} in workflow namespace "
                    f"{ns!r}: its producer has not completed (or its result "
                    f"was not journal-serializable on resume)")
            return default
        return value

    def has(self, ns: str, name: str) -> bool:
        with self._lock:
            return (ns, name) in self._data

    def names(self, ns: str) -> List[str]:
        with self._lock:
            return [n for (s, n) in self._data if s == ns]

    def clear_namespace(self, ns: str) -> int:
        with self._lock:
            keys = [k for k in self._data if k[0] == ns]
            for k in keys:
                del self._data[k]
            return len(keys)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


#: The single per-process store all components share (a store instance per
#: AppManager would leave the RTS-side trampoline, which only sees task
#: kwargs, with no way to find "its" store).
STORE = ResultStore()
