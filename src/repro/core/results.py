"""Process-global output store: task results routed to their consumers.

The declarative API (``repro.api``) compiles data-flow edges — a task
consuming another task's *return value* — down to PST tasks whose kwargs
carry ``{"__future__": <producer name>}`` placeholders. Somebody has to hold
the produced values between the producer's completion and the consumer's
execution; that is this store.

* **Writer**: the WFProcessor's Dequeue routes ``task.result`` here when a
  task tagged with a workflow namespace (``task.tags["_wf_ns"]``) reaches
  DONE — before the stage-closure accounting that makes the consumer's stage
  schedulable, so a consumer can never execute before its inputs are
  readable. Adaptive combinators (``repeat_until``/``branch``) additionally
  write their aggregate values from their ``post_exec`` hooks.
* **Reader**: the API trampoline (``repro.api.runtime``) resolves
  placeholders at execution time, RTS-side; ``Future.result()`` reads the
  same keys after the run.
* **Resume**: the AppManager preloads replayed journal results for
  resumed-DONE tasks before the workflow starts, so consumers of tasks
  completed in a previous session still find their inputs.

Keys are ``(namespace, task name)``: the namespace is minted per
``api.compile()`` call, so concurrent workflows in one process (tests, the
federation benchmarks) never collide even when task names repeat.

The store is deliberately process-global and unbounded for the lifetime of a
namespace — values stay readable after the run for ``Future.result()``.
Long-lived processes that run many workflows should call
:meth:`ResultStore.clear_namespace` (``api`` does this in
``Compiled.close()``).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, List, Tuple

from .exceptions import MissingError

_MISSING = object()


class ResultStore:
    """Thread-safe ``(namespace, name) -> value`` map."""

    def __init__(self) -> None:
        self._data: Dict[Tuple[str, str], Any] = {}
        self._lock = threading.Lock()

    def put(self, ns: str, name: str, value: Any) -> None:
        with self._lock:
            self._data[(ns, name)] = value

    def get(self, ns: str, name: str, default: Any = _MISSING) -> Any:
        with self._lock:
            value = self._data.get((ns, name), _MISSING)
        if value is _MISSING:
            if default is _MISSING:
                raise MissingError(
                    f"no result for task {name!r} in workflow namespace "
                    f"{ns!r}: its producer has not completed (or its result "
                    f"was not journal-serializable on resume)")
            return default
        return value

    def has(self, ns: str, name: str) -> bool:
        with self._lock:
            return (ns, name) in self._data

    def names(self, ns: str) -> List[str]:
        with self._lock:
            return [n for (s, n) in self._data if s == ns]

    def clear_namespace(self, ns: str) -> int:
        with self._lock:
            keys = [k for k in self._data if k[0] == ns]
            for k in keys:
                del self._data[k]
            return len(keys)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


#: The single per-process store all components share (a store instance per
#: AppManager would leave the RTS-side trampoline, which only sees task
#: kwargs, with no way to find "its" store).
STORE = ResultStore()
