"""Synchronizer: the AppManager subcomponent that owns the global state record.

Single consumer of the ``states`` queue. For every transition message it
(1) journals the transition (write-ahead), (2) updates the AppManager's
state table, and (3) acknowledges transactional messages. Because it is the
only writer of the journal and the state table, transitions are totally
ordered — the property the paper relies on for resumability.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, Optional

from .broker import Broker
from .journal import Journal
from .state_service import STATES_QUEUE


class Synchronizer:
    def __init__(self, broker: Broker, journal: Journal,
                 state_table: Dict[str, str],
                 on_transition: Optional[Callable[[Dict[str, Any]], None]] = None,
                 batch: int = 256) -> None:
        self.broker = broker
        self.journal = journal
        self.state_table = state_table  # shared with AppManager: f"{kind}:{name}" -> state
        self.on_transition = on_transition
        self.batch = batch
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.processed = 0
        self.crash_hook: Optional[Callable[[], None]] = None  # test injection

    def start(self) -> None:
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="synchronizer")
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        if drain:
            # give the loop a chance to empty the queue
            for _ in range(200):
                if self.broker.depth(STATES_QUEUE) == 0:
                    break
                threading.Event().wait(0.01)
        self._stop.set()
        self.broker.kick(STATES_QUEUE)
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None
        self.journal.flush()

    def is_alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _loop(self) -> None:
        while not self._stop.is_set():
            # event-driven: block until transitions arrive (or stop kicks);
            # popped messages are always processed, even mid-shutdown, so a
            # transactional advance is never left waiting on its ack
            msgs = self.broker.get_many(STATES_QUEUE, self.batch, timeout=None,
                                        abort=self._stop)
            if self.crash_hook is not None:
                self.crash_hook()
            if not msgs:
                continue
            needs_flush = False
            for _tag, msg in msgs:
                if msg.get("type") != "transition":
                    continue
                extra = dict(msg.get("extra", {}))
                if "via" in msg:  # coalesced transition chain
                    extra["via"] = msg["via"]
                if "ns" in msg:   # workflow namespace: per-tenant routing
                    extra["ns"] = msg["ns"]
                self.journal.transition(
                    kind=msg["kind"], uid=msg["uid"], name=msg["name"],
                    frm=msg["frm"], to=msg["to"], **extra)
                self.state_table[f"{msg['kind']}:{msg['name']}"] = msg["to"]
                self.processed += 1
                if self.on_transition is not None:
                    self.on_transition(msg)
                if "_ack" in msg:
                    needs_flush = True
            if needs_flush:
                # transactional messages: force the WAL to disk before acking
                self.journal.flush()
            for _tag, msg in msgs:
                ack = msg.get("_ack")
                if ack is not None:
                    ack.set()
            self.broker.ack_many(STATES_QUEUE, [tag for tag, _ in msgs])
