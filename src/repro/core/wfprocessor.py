"""WFProcessor: the workflow-management component (paper §II-B.2/3).

Two subcomponents, each a restartable thread:

* **Enqueue** — walks the pipelines, tags schedulable tasks (stage-ordering
  semantics of the PST model) and pushes them onto the ``pending`` queue.
* **Dequeue** — pulls completions from the ``done`` queue, tags tasks DONE /
  FAILED / CANCELED from the RTS return code, drives resubmission of failed
  tasks within their retry budgets, closes out stages and pipelines, and
  fires the adaptivity (``post_exec``) hooks.

Both loops are stateless between iterations: all state lives in the master
PST objects and the queues, which is what makes component restart after a
crash safe (failure model, §II-B.4).
"""

from __future__ import annotations

import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional

from . import states as st
from .broker import Broker
from .profiler import (DATA_STAGING, ENTK_MANAGEMENT, TASK_EXECUTION,
                       Profiler)
from .pst import Pipeline, Stage, Task
from .state_service import StateService

PENDING_QUEUE = "pending"
DONE_QUEUE = "done"


class WFProcessor:
    """Drives an application (list of pipelines) through the PST semantics."""

    def __init__(
        self,
        broker: Broker,
        svc: StateService,
        prof: Profiler,
        pipelines: List[Pipeline],
        task_index: Dict[str, Task],
        on_task_failure: str = "continue",  # or "fail_stage"
        resumed_done: Optional[set] = None,
    ) -> None:
        self.broker = broker
        self.svc = svc
        self.prof = prof
        self.pipelines = pipelines
        self.task_index = task_index
        self.on_task_failure = on_task_failure
        self.resumed_done = resumed_done or set()
        broker.declare(PENDING_QUEUE)
        broker.declare(DONE_QUEUE)
        self._stop = threading.Event()
        self._enqueue_thread: Optional[threading.Thread] = None
        self._dequeue_thread: Optional[threading.Thread] = None
        self._lock = threading.RLock()
        self.enqueue_crash_hook: Optional[Callable[[], None]] = None
        self.dequeue_crash_hook: Optional[Callable[[], None]] = None
        self.component_errors: List[str] = []

    # -- lifecycle ----------------------------------------------------------#

    def start(self) -> None:
        self._stop.clear()
        self.start_enqueue()
        self.start_dequeue()

    def start_enqueue(self) -> None:
        self._enqueue_thread = threading.Thread(
            target=self._guarded, args=(self._enqueue_loop, "enqueue"),
            daemon=True, name="wfp-enqueue")
        self._enqueue_thread.start()

    def start_dequeue(self) -> None:
        self._dequeue_thread = threading.Thread(
            target=self._guarded, args=(self._dequeue_loop, "dequeue"),
            daemon=True, name="wfp-dequeue")
        self._dequeue_thread.start()

    def stop(self) -> None:
        self._stop.set()
        for t in (self._enqueue_thread, self._dequeue_thread):
            if t is not None:
                t.join(timeout=5.0)
        self._enqueue_thread = None
        self._dequeue_thread = None

    def threads_alive(self) -> Dict[str, bool]:
        return {
            "enqueue": bool(self._enqueue_thread
                            and self._enqueue_thread.is_alive()),
            "dequeue": bool(self._dequeue_thread
                            and self._dequeue_thread.is_alive()),
        }

    def _guarded(self, fn: Callable[[], None], name: str) -> None:
        try:
            fn()
        except Exception:  # noqa: BLE001 - component crash, recorded for restart
            self.component_errors.append(
                f"{name}: {traceback.format_exc(limit=5)}")

    # -- completion condition -------------------------------------------------#

    @property
    def workflow_final(self) -> bool:
        return all(p.is_final for p in self.pipelines)

    # -- Enqueue ------------------------------------------------------------#

    def _enqueue_loop(self) -> None:
        while not self._stop.is_set():
            if self.enqueue_crash_hook is not None:
                self.enqueue_crash_hook()
            worked = self._schedule_pass()
            if not worked:
                time.sleep(0.01)

    def _schedule_pass(self) -> bool:
        """One scheduling sweep; returns True if any work was done."""
        t0 = time.perf_counter()
        worked = False
        with self._lock:
            for pipe in self.pipelines:
                if pipe.is_final:
                    continue
                if pipe.state == st.PIPELINE_INITIAL:
                    self.svc.advance(pipe, st.PIPELINE_SCHEDULING,
                                     transact=False)
                    worked = True
                stage = pipe.next_stage()
                if stage is None:
                    if pipe.completed and not pipe.is_final:
                        self._finalize_pipeline(pipe)
                        worked = True
                    continue
                if stage.state == st.STAGE_INITIAL:
                    self._schedule_stage(pipe, stage)
                    worked = True
        if worked:
            self.prof.add(ENTK_MANAGEMENT, time.perf_counter() - t0)
        return worked

    def _schedule_stage(self, pipe: Pipeline, stage: Stage) -> None:
        self.svc.advance(stage, st.STAGE_SCHEDULING, transact=False)
        payload = []
        for task in stage.tasks:
            # index here (not only at startup): adaptive post_exec hooks
            # append stages at runtime and their tasks must be resolvable
            # by the ExecManager and Dequeue
            self.task_index[task.uid] = task
            if task.name in self.resumed_done and not task.is_final:
                # resume: completed in a previous session, skip execution
                self.svc.advance(task, st.SCHEDULING, transact=False)
                self.svc.advance(task, st.SCHEDULED, transact=False)
                self.svc.advance(task, st.SUBMITTING, transact=False)
                self.svc.advance(task, st.SUBMITTED, transact=False)
                self.svc.advance(task, st.EXECUTED, transact=False)
                self.svc.advance(task, st.DONE, resumed=True)
                continue
            if task.is_final:
                continue
            self.svc.advance(task, st.SCHEDULING, transact=False)
            payload.append(task.uid)
            self.svc.advance(task, st.SCHEDULED, transact=False)
        if payload:
            self.broker.put_many(PENDING_QUEUE, payload)
        self.svc.advance(stage, st.STAGE_SCHEDULED, transact=False)
        # A stage whose every task was resumed completes immediately.
        self._maybe_finalize_stage(pipe, stage)

    # -- Dequeue ------------------------------------------------------------#

    def _dequeue_loop(self) -> None:
        while not self._stop.is_set():
            if self.dequeue_crash_hook is not None:
                self.dequeue_crash_hook()
            msgs = self.broker.get_many(DONE_QUEUE, 256, timeout=0.05)
            if not msgs:
                continue
            t0 = time.perf_counter()
            for tag, msg in msgs:
                try:
                    self._handle_completion(msg)
                finally:
                    self.broker.ack(DONE_QUEUE, tag)
            self.prof.add(ENTK_MANAGEMENT, time.perf_counter() - t0)

    def _handle_completion(self, msg: Dict[str, Any]) -> None:
        uid = msg["uid"]
        task = self.task_index.get(uid)
        if task is None or task.is_final:
            return  # duplicate (e.g. the losing speculative attempt)
        task.exit_code = msg.get("exit_code")
        task.exception = msg.get("exception")
        task.result = msg.get("result")
        task.completed_at = msg.get("completed_at")
        self.prof.add(TASK_EXECUTION, float(msg.get("execution_seconds", 0.0)))
        self.prof.add(DATA_STAGING, float(msg.get("staging_seconds", 0.0)))

        with self._lock:
            if msg.get("canceled") or msg.get("exit_code") == -2:
                self.svc.advance(task, st.CANCELED)
            elif msg.get("exit_code") == 0:
                self.svc.advance(task, st.DONE)
            else:
                self.svc.advance(task, st.FAILED,
                                 exc=str(msg.get("exception", ""))[:500])
                if task.retries < task.max_retries:
                    # resubmission path (paper: multiple attempts without
                    # restarting completed tasks)
                    task.retries += 1
                    self.svc.advance(task, st.SCHEDULING, transact=False,
                                     retry=task.retries)
                    self.svc.advance(task, st.SCHEDULED, transact=False)
                    self.broker.put(PENDING_QUEUE, task.uid)
                    return
            stage = self._find_stage(task)
            pipe = self._find_pipeline(task)
            if stage is not None and pipe is not None:
                self._maybe_finalize_stage(pipe, stage)

    # -- stage / pipeline closure -----------------------------------------------#

    def _find_stage(self, task: Task) -> Optional[Stage]:
        pipe = self._find_pipeline(task)
        if pipe is None:
            return None
        for s in pipe.stages:
            if s.uid == task.parent_stage:
                return s
        return None

    def _find_pipeline(self, task: Task) -> Optional[Pipeline]:
        for p in self.pipelines:
            if p.uid == task.parent_pipeline:
                return p
        return None

    def _maybe_finalize_stage(self, pipe: Pipeline, stage: Stage) -> None:
        if stage.state != st.STAGE_SCHEDULED:
            return
        if not all(t.is_final for t in stage.tasks):
            return
        any_failed = any(t.state == st.FAILED for t in stage.tasks)
        if any_failed and self.on_task_failure == "fail_stage":
            self.svc.advance(stage, st.STAGE_FAILED)
            pipe.mark_stage_final(stage.uid)
            self.svc.advance(pipe, st.PIPELINE_FAILED)
            return
        self.svc.advance(stage, st.STAGE_DONE)
        pipe.mark_stage_final(stage.uid)
        if stage.post_exec is not None:
            # adaptivity: the hook may append stages to the pipeline
            try:
                stage.post_exec(stage, pipe)
            except Exception:  # noqa: BLE001 - user hook, never fatal
                self.component_errors.append(
                    f"post_exec[{stage.uid}]: {traceback.format_exc(limit=5)}")
        if pipe.completed and not pipe.is_final:
            self._finalize_pipeline(pipe)

    def _finalize_pipeline(self, pipe: Pipeline) -> None:
        any_failed = any(
            t.state == st.FAILED for s in pipe.stages for t in s.tasks)
        to = st.PIPELINE_FAILED if (any_failed and
                                    self.on_task_failure == "fail_stage") \
            else st.PIPELINE_DONE
        if pipe.state == st.PIPELINE_INITIAL:
            self.svc.advance(pipe, st.PIPELINE_SCHEDULING, transact=False)
        self.svc.advance(pipe, to)
