"""WFProcessor: the workflow-management component (paper §II-B.2/3).

Two subcomponents, each a restartable thread, both **event-driven** (no
sleep-polling anywhere on the hot path):

* **Enqueue** — blocks on the ``schedule`` queue of *dirty pipeline* uids.
  A pipeline is marked dirty when it first enters the workflow, when one of
  its stages closes, or when an adaptive ``post_exec`` hook appends stages
  at runtime (the Pipeline's append listener fires on ``add_stages``). Each
  wakeup schedules exactly the pipelines that changed — per-event cost is
  O(changed pipelines), not O(all pipelines).
* **Dequeue** — blocks on the ``done`` queue. Each completion routes to its
  (task, stage, pipeline) triple through the :class:`WorkflowIndex` in O(1)
  and closes stages/pipelines through per-stage pending counters in O(1),
  instead of re-scanning ``all(t.is_final ...)`` per event.

Both loops are stateless between iterations: all state lives in the master
PST objects and the queues, which is what makes component restart after a
crash safe (failure model, §II-B.4).
"""

from __future__ import annotations

import json
import threading
import time
import traceback
from typing import Any, Callable, Dict, List, Optional

from .. import telemetry as tel
from . import states as st
from .broker import Broker
from .profiler import (DATA_STAGING, ENTK_MANAGEMENT, TASK_EXECUTION,
                       Profiler)
from .policies import INFRA, RETRY_TOTAL, TASK, RetryPolicy
from .pst import Pipeline, Stage, Task, WorkflowIndex
from .results import STORE as RESULTS
from .results import decode_journal_value, spill_journal_value
from .state_service import StateService

PENDING_QUEUE = "pending"
DONE_QUEUE = "done"
SCHEDULE_QUEUE = "schedule"   # dirty-pipeline notification channel


class WFProcessor:
    """Drives an application (list of pipelines) through the PST semantics."""

    #: Largest JSON-encoded task result (bytes) journaled on DONE records.
    RESULT_JOURNAL_CAP = 256 * 1024

    def __init__(
        self,
        broker: Broker,
        svc: StateService,
        prof: Profiler,
        pipelines: List[Pipeline],
        index: WorkflowIndex,
        on_task_failure: str = "continue",  # or "fail_stage"
        resumed_done: Optional[set] = None,
        resumed_results: Optional[Dict[str, Any]] = None,
        result_omitted: Optional[set] = None,
        spill_dir: Optional[str] = None,
        retry_policy: Optional[RetryPolicy] = None,
    ) -> None:
        self.broker = broker
        self.svc = svc
        self.prof = prof
        self.pipelines = pipelines
        self.index = index
        self.on_task_failure = on_task_failure
        self.resumed_done = resumed_done or set()
        # journal-replayed task return values / names whose value could not
        # be journaled; applied at *scheduling* time so stages appended at
        # runtime (adaptive rounds) restore results exactly like static ones
        self.resumed_results = resumed_results or {}
        self.result_omitted = result_omitted or set()
        # sidecar directory for results too rich to JSON onto a DONE record
        # (fused array handles journal a content hash + spill path instead)
        self.spill_dir = spill_dir
        # Unified retry channel (chaos plane): one policy decides budgets
        # and backoff for BOTH fault classes — infra (pilot_lost, uncharged
        # by default) and task (charged against task.max_retries). The
        # default policy reproduces the historical behaviour exactly.
        self.retry_policy = retry_policy or RetryPolicy()
        self._infra_retries: Dict[str, int] = {}     # uid -> uncharged hops
        self._first_failure: Dict[str, float] = {}   # uid -> monotonic t0
        self._retry_timers: List[threading.Timer] = []
        self.backoff_requeues = 0
        # Superstage scheduling (chain fusion): when the RTS composes
        # ``_fusion_chain``-tagged stages (JaxRTS.supports_chain_fusion),
        # a chain's downstream stages are handed off TOGETHER with its
        # entry stage so the RTS can run the whole chain on one lease —
        # the control plane stops sitting between the links. Off (the
        # default), stage ordering gates submissions exactly as before;
        # the AppManager flips it per run after acquiring resources.
        self.chain_scheduling = False
        broker.declare(PENDING_QUEUE)
        broker.declare(DONE_QUEUE)
        broker.declare(SCHEDULE_QUEUE)
        self._stop = threading.Event()
        self._enqueue_thread: Optional[threading.Thread] = None
        self._dequeue_thread: Optional[threading.Thread] = None
        # fallback for completions that cannot be routed to a pipeline
        # (scheduling/closure otherwise lock per-pipeline) + closure counting
        self._lock = threading.RLock()
        self.enqueue_crash_hook: Optional[Callable[[], None]] = None
        self.dequeue_crash_hook: Optional[Callable[[], None]] = None
        self.component_errors: List[str] = []
        # Event-driven completion signal: the AppManager waits on this
        # instead of polling workflow_final.
        self.done_event = threading.Event()
        self._open_pipelines = len(pipelines)
        # Serving mode (multi-tenant daemon): pipelines may be added while
        # the loops run (`add_pipelines`), per-workflow resume state is
        # registered per namespace (`add_resumed_namespace`), and every
        # pipeline closure is reported through this hook so submission
        # handles can complete individually — done_event then only means
        # "idle right now", not "drained forever".
        self.on_pipeline_final: Optional[Callable[[Pipeline], None]] = None
        self._ns_resume: Dict[str, tuple] = {}
        self._ns_spill: Dict[str, str] = {}
        # Iteration counters (observability + the no-busy-wait tests): a
        # schedule pass only happens when a pipeline was actually dirty, so
        # an idle workflow performs zero passes no matter how long it idles.
        self.schedule_passes = 0
        self.dequeue_batches = 0

    # -- lifecycle ----------------------------------------------------------#

    def start(self) -> None:
        self._stop.clear()
        for pipe in self.pipelines:
            pipe.set_append_listener(self._mark_dirty)
        self.start_enqueue()
        self.start_dequeue()
        # Seed the ready set: every pipeline is dirty until first scheduled
        # (one queue operation, not one per pipeline).
        self.broker.put_many(SCHEDULE_QUEUE,
                             [pipe.uid for pipe in self.pipelines])

    def start_enqueue(self) -> None:
        self._enqueue_thread = threading.Thread(
            target=self._guarded, args=(self._enqueue_loop, "enqueue"),
            daemon=True, name="wfp-enqueue")
        self._enqueue_thread.start()

    def start_dequeue(self) -> None:
        self._dequeue_thread = threading.Thread(
            target=self._guarded, args=(self._dequeue_loop, "dequeue"),
            daemon=True, name="wfp-dequeue")
        self._dequeue_thread.start()

    def stop(self) -> None:
        self._stop.set()
        with self._lock:
            timers, self._retry_timers = self._retry_timers, []
        for timer in timers:
            timer.cancel()
        self.broker.kick(SCHEDULE_QUEUE)
        self.broker.kick(DONE_QUEUE)
        for t in (self._enqueue_thread, self._dequeue_thread):
            if t is not None:
                t.join(timeout=5.0)
        self._enqueue_thread = None
        self._dequeue_thread = None

    # -- serving mode (multi-tenant daemon) ----------------------------------#

    def add_pipelines(self, pipelines: List[Pipeline]) -> None:
        """Admit pipelines into a *running* processor (serving mode).

        The caller must have registered them in the WorkflowIndex first
        (``index.add_pipeline``) so Dequeue can route their completions the
        instant they are visible on the schedule queue.
        """
        with self._lock:
            self.pipelines.extend(pipelines)
            self._open_pipelines += len(pipelines)
            self.done_event.clear()
        for pipe in pipelines:
            pipe.set_append_listener(self._mark_dirty)
        self.broker.put_many(SCHEDULE_QUEUE, [p.uid for p in pipelines])

    def add_resumed_namespace(self, ns: str, done: set,
                              results: Dict[str, Any], omitted: set,
                              spill_dir: Optional[str] = None) -> None:
        """Register journal-replayed resume state scoped to one workflow
        namespace: a resubmitted tenant workflow restores only ITS OWN
        completed tasks even when task names collide across tenants."""
        with self._lock:
            self._ns_resume[ns] = (done, results, omitted)
            if spill_dir is not None:
                self._ns_spill[ns] = spill_dir

    def _resume_for(self, task: Task) -> tuple:
        """(done, results, omitted) governing ``task``'s resume: the
        namespace-scoped set when its workflow registered one, else the
        run-wide replay the classic single-workflow path installs."""
        ns = task.tags.get("_wf_ns")
        if ns is not None and ns in self._ns_resume:
            return self._ns_resume[ns]
        return self.resumed_done, self.resumed_results, self.result_omitted

    def _spill_dir_for(self, task: Task) -> Optional[str]:
        ns = task.tags.get("_wf_ns")
        if ns is not None and ns in self._ns_spill:
            return self._ns_spill[ns]
        return self.spill_dir

    def note_pipeline_closed(self, pipe: Pipeline) -> None:
        """Account a pipeline finalized OUTSIDE the completion chain (the
        service's cancel path advances it to CANCELED itself): decrement the
        open count and fire the closure hook exactly once."""
        with self._lock:
            self._open_pipelines -= 1
            if self._open_pipelines <= 0:
                self.done_event.set()
        if self.on_pipeline_final is not None:
            try:
                self.on_pipeline_final(pipe)
            except Exception:  # noqa: BLE001 - service hook, never fatal
                self.component_errors.append(
                    f"on_pipeline_final[{pipe.uid}]: "
                    f"{traceback.format_exc(limit=5)}")

    def threads_alive(self) -> Dict[str, bool]:
        return {
            "enqueue": bool(self._enqueue_thread
                            and self._enqueue_thread.is_alive()),
            "dequeue": bool(self._dequeue_thread
                            and self._dequeue_thread.is_alive()),
        }

    def _guarded(self, fn: Callable[[], None], name: str) -> None:
        try:
            fn()
        except Exception:  # noqa: BLE001 - component crash, recorded for restart
            self.component_errors.append(
                f"{name}: {traceback.format_exc(limit=5)}")

    # -- completion condition -------------------------------------------------#

    @property
    def workflow_final(self) -> bool:
        return all(p.is_final for p in self.pipelines)

    # -- dirty-pipeline channel ----------------------------------------------#

    def _mark_dirty(self, pipe_uid: str) -> None:
        """Notify Enqueue that ``pipe_uid`` needs a scheduling visit."""
        self.broker.put(SCHEDULE_QUEUE, pipe_uid)

    # -- Enqueue ------------------------------------------------------------#

    def _enqueue_loop(self) -> None:
        while not self._stop.is_set():
            msgs = self.broker.get_many(SCHEDULE_QUEUE, 256, timeout=None,
                                        abort=self._stop)
            if self._stop.is_set():
                return
            if self.enqueue_crash_hook is not None:
                self.enqueue_crash_hook()
            if not msgs:
                continue  # kicked awake; nothing dirty
            t0 = time.perf_counter()
            seen = set()
            done_tags = []
            sink: List[Any] = []
            pending: List[str] = []
            with tel.span("wfp.enqueue_batch", "wfp", msgs=len(msgs)):
                try:
                    for tag, uid in msgs:
                        # schedule before ack: a crash mid-batch leaves dirty
                        # marks unacked for redelivery; re-visits are
                        # idempotent
                        if uid not in seen:
                            seen.add(uid)
                            pipe = self.index.pipeline(uid)
                            if pipe is not None:
                                self.schedule_passes += 1
                                self._schedule_pipeline(pipe, sink, pending)
                        done_tags.append(tag)
                finally:
                    self.svc.flush(sink)
                    if pending:
                        # one pending-queue hand-off for the whole dirty batch
                        self.broker.put_many(PENDING_QUEUE, pending)
                    self.broker.ack_many(SCHEDULE_QUEUE, done_tags)
            self.prof.add(ENTK_MANAGEMENT, time.perf_counter() - t0)

    def _schedule_pipeline(self, pipe: Pipeline,
                           sink: Optional[List[Any]] = None,
                           pending: Optional[List[str]] = None) -> None:
        """Visit one dirty pipeline: advance its cursor as far as possible.

        Locking is per-pipeline: Enqueue scheduling pipeline A never
        contends with Dequeue closing a task of pipeline B (a global lock
        here measurably dominated management overhead at O(10⁴) pipelines).
        State publishes defer into ``sink``; ordering toward Dequeue is
        guaranteed because the pending hand-off (which is what makes
        completions for these objects possible at all) happens only after
        the sink is flushed — see the ``finally`` ordering in the enqueue
        loop and in this function's own-buffer path.
        """
        own = sink is None
        if own:
            sink = []
        own_pending: List[str] = [] if pending is None else None
        if own_pending is not None:
            pending = own_pending
        try:
            with pipe.lock:
                if pipe.is_final:
                    return
                if pipe.state == st.PIPELINE_INITIAL:
                    self.svc.advance(pipe, st.PIPELINE_SCHEDULING,
                                     transact=False, sink=sink)
                while True:
                    stage = pipe.next_stage()
                    if stage is None:
                        if pipe.completed and not pipe.is_final:
                            self._finalize_pipeline(pipe, sink=sink)
                        return
                    if stage.state != st.STAGE_INITIAL:
                        return  # current stage still executing
                    self._schedule_stage(pipe, stage, sink, pending)
                    if not stage.is_final:
                        if self.chain_scheduling:
                            # superstage: a fused chain's downstream link
                            # stages ride the same hand-off so the RTS can
                            # compose the whole chain on one device lease
                            self._schedule_chain_successors(
                                pipe, stage, sink, pending)
                        return  # in flight; completions drive progress
                    # stage closed instantly (fully resumed): advance on
        finally:
            if own:
                self.svc.flush(sink)
            if own_pending:
                self.broker.put_many(PENDING_QUEUE, own_pending)

    def _schedule_stage(self, pipe: Pipeline, stage: Stage,
                        sink: Optional[List[Any]] = None,
                        pending: Optional[List[str]] = None) -> None:
        # register here (not only at startup): adaptive post_exec hooks
        # append stages at runtime and their tasks must be resolvable by the
        # ExecManager and Dequeue through the WorkflowIndex
        self.index.add_stage(stage)
        payload = []
        for task in stage.tasks:
            resumed_done, _, _ = self._resume_for(task)
            if (task.name in resumed_done
                    and task.state == st.INITIAL
                    and not self._result_lost(task)
                    and self._restore_resumed(task, sink)):
                continue
            if task.is_final:
                continue
            if task.state == st.INITIAL:
                self.svc.advance_seq(task, (st.SCHEDULING, st.SCHEDULED),
                                     transact=False, sink=sink)
                payload.append(task.uid)
            elif task.state == st.SCHEDULED:
                # crash-recovery re-visit: the task was advanced but the
                # pending hand-off may have been lost — hand it off again
                # (the ExecManager deduplicates against its backlog and
                # custody), and never re-run the SCHEDULING chain
                payload.append(task.uid)
            # other states: already with the ExecManager/RTS
        # Arm the O(1) closure countdown before any completion can race in
        # (we hold pipe.lock; Dequeue takes it before decrementing).
        # Counting non-final tasks (not len(payload)) keeps re-visits exact.
        stage.begin_execution(sum(1 for t in stage.tasks if not t.is_final))
        if payload:
            if pending is not None:
                # deferred hand-off: the caller publishes the whole dirty
                # batch to the pending queue in one operation, after the
                # state sink is flushed
                pending.extend(payload)
            else:
                if sink is not None:
                    # the ExecManager may advance these tasks as soon as
                    # they are visible on the pending queue
                    self.svc.flush(sink)
                self.broker.put_many(PENDING_QUEUE, payload)
        self.svc.advance_seq(stage, (st.STAGE_SCHEDULING, st.STAGE_SCHEDULED),
                             transact=False, sink=sink)
        # A stage whose every task was resumed completes immediately.
        self._maybe_finalize_stage(pipe, stage, sink=sink)

    # -- superstage (chain/DAG fusion) ---------------------------------------#

    #: Task.tags keys stamped by the api compiler's chain/DAG detection
    #: (kept as literals here: the core must not import the fusion package).
    CHAIN_TAG = "_fusion_chain"
    DAG_TAG = "_fusion_dag"

    @classmethod
    def _flow_tag(cls, task) -> Optional[Dict[str, Any]]:
        """The task's chain OR DAG tag — both carry ``c``/``k`` and both
        superstage identically (a DAG is a chain of *nodes*: ensembles and
        fan-in reductions; node indices advance exactly like link
        indices). A task is on at most one flow."""
        tag = task.tags.get(cls.CHAIN_TAG)
        if tag is None:
            tag = task.tags.get(cls.DAG_TAG)
        if (isinstance(tag, dict) and isinstance(tag.get("c"), str)
                and isinstance(tag.get("k"), int)):
            return tag
        return None

    @classmethod
    def _stage_chain_links(cls, stage: Stage) -> Optional[Dict[str, set]]:
        """``{chain/DAG id: {link indices}}`` when EVERY task of the stage
        is a chain link or DAG node member, else None (a mixed stage never
        superstages — its untagged tasks would be submitted ahead of their
        input routing)."""
        sig: Dict[str, set] = {}
        for task in stage.tasks:
            tag = cls._flow_tag(task)
            if tag is None:
                return None
            sig.setdefault(tag["c"], set()).add(tag["k"])
        return sig or None

    def _schedule_chain_successors(self, pipe: Pipeline, stage: Stage,
                                   sink: Optional[List[Any]],
                                   pending: Optional[List[str]]) -> None:
        """Hand off the consecutive stages that continue ``stage``'s chains.

        Stage *i+1* continues stage *i* when every one of its tasks is a
        chain link whose (chain, link) is exactly one past a (chain, link)
        in stage *i*. The whole run lands in ONE pending-queue hand-off
        (the caller's batched ``put_many``), which is what lets the Emgr's
        whole-chain drain and the JaxRTS's chain assembler see complete
        member chains. Called under ``pipe.lock``.
        """
        sig = self._stage_chain_links(stage)
        if not sig:
            return
        try:
            idx = pipe.stages.index(stage)
        except ValueError:  # pragma: no cover - stage always belongs to pipe
            return
        published = [stage]
        for nxt in pipe.stages[idx + 1:]:
            nsig = self._stage_chain_links(nxt)
            if not nsig:
                break
            continues = all(
                c in sig and all(k - 1 in sig[c] for k in links)
                for c, links in nsig.items())
            if not continues:
                break
            if nxt.state == st.STAGE_INITIAL:
                self._schedule_stage(pipe, nxt, sink, pending)
            published.append(nxt)
            sig = nsig
        if len(published) < 2:
            return
        tel.counter("wfp_superstages_total").inc()
        tel.histogram("wfp_superstage_stages").observe(len(published))
        # stamp the superstage EXTENT ("ss" = highest co-published link per
        # chain) onto every published link task: the Emgr only holds a
        # chain fragment for links it knows were co-published — a chain
        # that could not superstage (mixed stage, gated continuation) flows
        # stage by stage with zero hold latency, per-stage fused
        extent: Dict[str, int] = {}
        for s in published:
            for task in s.tasks:
                tag = self._flow_tag(task)
                if tag is not None:
                    extent[tag["c"]] = max(extent.get(tag["c"], 0), tag["k"])
        for s in published:
            for task in s.tasks:
                tag = self._flow_tag(task)
                if tag is not None:
                    tag["ss"] = extent[tag["c"]]

    # -- Dequeue ------------------------------------------------------------#

    def _dequeue_loop(self) -> None:
        while not self._stop.is_set():
            msgs = self.broker.get_many(DONE_QUEUE, 256, timeout=None,
                                        abort=self._stop)
            if self._stop.is_set():
                return
            if self.dequeue_crash_hook is not None:
                self.dequeue_crash_hook()
            if not msgs:
                continue  # kicked awake
            self.dequeue_batches += 1
            t0 = time.perf_counter()
            done_tags = []
            sink: List[Any] = []
            exec_s = staging_s = 0.0
            n_handled = 0
            span = tel.span("wfp.dequeue_batch", "wfp", msgs=len(msgs))
            try:
                for tag, msg in msgs:
                    # tag first: a message that crashes the handler is acked
                    # (dropped) rather than redelivered into a crash loop
                    done_tags.append(tag)
                    if self._handle_completion(msg, sink):
                        exec_s += float(msg.get("execution_seconds", 0.0))
                        staging_s += float(msg.get("staging_seconds", 0.0))
                        n_handled += 1
            finally:
                self.svc.flush(sink)
                # one lock round for the whole batch; a crash mid-batch
                # leaves only the untouched suffix for redelivery
                self.broker.ack_many(DONE_QUEUE, done_tags)
                span.set(handled=n_handled).end()
            if n_handled:
                # per-batch accumulation: Profiler.add takes a global lock
                self.prof.add(TASK_EXECUTION, exec_s, count=n_handled)
                self.prof.add(DATA_STAGING, staging_s, count=n_handled)
            self.prof.add(ENTK_MANAGEMENT, time.perf_counter() - t0)

    def _handle_completion(self, msg: Dict[str, Any],
                           sink: Optional[List[Any]] = None) -> bool:
        """Process one completion; returns False for filtered duplicates
        (the caller accounts execution/staging time for handled ones)."""
        uid = msg["uid"]
        task, stage, pipe = self.index.route(uid)
        if task is None or task.is_final:
            return False  # duplicate (e.g. the losing speculative attempt)
        task.exit_code = msg.get("exit_code")
        task.exception = msg.get("exception")
        task.result = msg.get("result")
        task.completed_at = msg.get("completed_at")

        with (pipe.lock if pipe is not None else self._lock):
            if task.is_final:
                return False  # canceled under the lock while we waited
            failed = False
            # the RTS callback no longer advances EXECUTED from the RTS's
            # own thread (one less hot-path synchronization point); the
            # completion chain is coalesced into a single published message
            prefix = (st.EXECUTED,) if task.state == st.SUBMITTED else ()
            policy = self.retry_policy
            if msg.get("pilot_lost"):
                # The pilot executing the task died (federation member
                # failover) — an infrastructure failure, not a task failure.
                # Re-journal FAILED (marked ``pilot_lost`` so resume does not
                # charge it against the retry budget) and requeue onto the
                # surviving members: failover must lose zero completions
                # even for max_retries=0 tasks. The infra channel is
                # unbounded by default; a RetryPolicy with
                # ``max_infra_retries`` caps flapping infrastructure.
                exc = str(msg.get("exception", ""))[:500]
                attempts = self._infra_retries.get(task.uid, 0)
                first = self._first_failure.setdefault(
                    task.uid, time.monotonic())
                if policy.should_retry(task, INFRA, attempts, first):
                    self._infra_retries[task.uid] = attempts + 1
                    tel.counter(RETRY_TOTAL, fault_class=INFRA).inc()
                    self.svc.advance_seq(task, prefix + (st.FAILED,), exc=exc,
                                         pilot_lost=True, sink=sink)
                    self.svc.advance_seq(task, (st.SCHEDULING, st.SCHEDULED),
                                         transact=False, sink=sink)
                    if sink is not None:
                        self.svc.flush(sink)  # hand-off to the ExecManager
                    self._requeue_pending(
                        task.uid, policy.delay(task.name, attempts + 1))
                    return True
                # infra budget exhausted: permanent failure (still journaled
                # pilot_lost so replay never charges the task budget)
                self.svc.advance_seq(task, prefix + (st.FAILED,), exc=exc,
                                     pilot_lost=True, sink=sink)
                self._forget_retry_state(task.uid)
                failed = True
                if stage is not None and pipe is not None:
                    stage.note_task_final(failed)
                    pipe.note_task_failed()
                    self._maybe_finalize_stage(pipe, stage, sink=sink)
                return True
            if msg.get("canceled") or msg.get("exit_code") == -2:
                self._forget_retry_state(task.uid)
                self.svc.advance_seq(task, prefix + (st.CANCELED,), sink=sink)
            elif msg.get("exit_code") == 0:
                self._forget_retry_state(task.uid)
                extras = self._route_result(task)
                if msg.get("plan") is not None:
                    # the fused carrier's chosen execution plan (mesh shape
                    # or lane count) rides the DONE record for postmortem
                    # perf debugging
                    extras.setdefault("plan", msg["plan"])
                self.svc.advance_seq(task, prefix + (st.DONE,),
                                     sink=sink, **extras)
            else:
                exc = str(msg.get("exception", ""))[:500]
                first = self._first_failure.setdefault(
                    task.uid, time.monotonic())
                if policy.should_retry(task, TASK, task.retries, first):
                    # resubmission path (paper: multiple attempts without
                    # restarting completed tasks); the task stays pending in
                    # its stage's countdown. The FAILED hop is published as
                    # its own message — Journal.replay counts discrete
                    # to=FAILED records to restore retry budgets on resume.
                    task.retries += 1
                    tel.counter(RETRY_TOTAL, fault_class=TASK).inc()
                    self.svc.advance_seq(task, prefix + (st.FAILED,),
                                         exc=exc, sink=sink)
                    self.svc.advance_seq(task, (st.SCHEDULING, st.SCHEDULED),
                                         transact=False,
                                         retry=task.retries, sink=sink)
                    if sink is not None:
                        self.svc.flush(sink)  # hand-off to the ExecManager
                    self._requeue_pending(
                        task.uid, policy.delay(task.name, task.retries))
                    return True
                self.svc.advance_seq(task, prefix + (st.FAILED,), exc=exc,
                                     sink=sink)
                self._forget_retry_state(task.uid)
                failed = True
            if stage is not None and pipe is not None:
                stage.note_task_final(failed)
                if failed:
                    pipe.note_task_failed()
                self._maybe_finalize_stage(pipe, stage, sink=sink)
        return True

    def _forget_retry_state(self, uid: str) -> None:
        """Drop per-uid retry bookkeeping once a task reaches a terminal
        state (or succeeds) — the dicts track only in-flight failures."""
        self._infra_retries.pop(uid, None)
        self._first_failure.pop(uid, None)

    def _requeue_pending(self, uid: str, delay: float) -> None:
        """Requeue a retried task, after the policy's backoff if any.

        Backoff rides a daemon Timer rather than blocking the Dequeue loop
        (one straggling retry must not stall the whole completion stream);
        a processor stop cancels outstanding timers."""
        if delay <= 0 or self._stop.is_set():
            self.broker.put(PENDING_QUEUE, uid)
            return
        self.backoff_requeues += 1
        timer = threading.Timer(delay, self._fire_requeue, args=(uid,))
        timer.daemon = True
        with self._lock:
            self._retry_timers = [t for t in self._retry_timers
                                  if t.is_alive()]
            self._retry_timers.append(timer)
        timer.start()

    def _fire_requeue(self, uid: str) -> None:
        if not self._stop.is_set():
            self.broker.put(PENDING_QUEUE, uid)

    def _restore_resumed(self, task: Task, sink: Optional[List[Any]]) -> bool:
        """Resume one task completed in a previous session: skip execution
        and restore its journaled result for data-flow consumers. Returns
        False — schedule the task normally, i.e. re-run the producer — when
        the journaled value cannot be decoded (a spilled fused-array whose
        sidecar file is missing or corrupted): consumers must never receive
        a silently-wrong input on resume."""
        _, resumed_results, _ = self._resume_for(task)
        if task.result is None and task.name in resumed_results:
            try:
                task.result = decode_journal_value(
                    resumed_results[task.name])
            except Exception:  # noqa: BLE001 - undecodable: re-run producer
                return False
        ns = task.tags.get("_wf_ns")
        if ns is not None and (task.name in resumed_results
                               or task.result is not None):
            RESULTS.put(ns, task.name, task.result)
        self.svc.advance_seq(
            task, (st.SCHEDULING, st.SCHEDULED, st.SUBMITTING,
                   st.SUBMITTED, st.EXECUTED, st.DONE),
            resumed=True, sink=sink)
        return True

    def _result_lost(self, task: Task) -> bool:
        """True when a DONE task's value never reached the journal and a
        data-flow consumer may need it: re-run the producer on resume
        instead of resuming it value-less."""
        _, _, result_omitted = self._resume_for(task)
        return (task.name in result_omitted
                and task.tags.get("_wf_ns") is not None)

    def _route_result(self, task: Task) -> Dict[str, Any]:
        """Route a DONE task's return value and decide its journal extra.

        Data-flow routing (declarative API): tasks compiled from
        ``repro.api`` carry their workflow namespace in
        ``task.tags['_wf_ns']``; their results go into the process-global
        :data:`~repro.core.results.STORE` *here* — before the stage-closure
        accounting below makes any consumer schedulable — so a consumer can
        never execute ahead of its inputs.

        Persistence: with a write-ahead journal behind the run, the result
        rides the DONE transition record so resume/replay restores it
        (consumers of a task completed in a previous session still find
        their inputs). Results that JSON cannot round-trip are journaled as
        ``result_omitted`` — replay then re-runs the producer instead of
        silently feeding consumers a corrupted value. Plain workloads
        (result ``None``, or no journal and no namespace) pay nothing.
        """
        ns = task.tags.get("_wf_ns")
        if ns is not None:
            # store even None: a consumer must see "produced None", never
            # "missing" (the store distinguishes the two)
            RESULTS.put(ns, task.name, task.result)
        if not self.svc.durable or (task.result is None and ns is None):
            return {}
        encode = getattr(task.result, "to_journal", None)
        if callable(encode):
            # rich result handle (fused device arrays): journal a tiny
            # codec record (content hash + spill path) instead of a JSON
            # encoding that would blow the result cap; with no sidecar
            # directory fall back to result_omitted → producer re-runs
            try:
                record = encode(self._spill_dir_for(task))
            except Exception:  # noqa: BLE001 - spill failed: omit, re-run
                record = None
            if record is not None:
                return {"result": record}
            return {"result_omitted": True}
        try:
            # must ROUND-TRIP, not merely serialize: int dict keys / tuples
            # survive dumps but come back mutated, which is exactly the
            # silent corruption result_omitted exists to prevent. The size
            # cap bounds both the journal (one JSONL line per result) and
            # this completion-path check; oversized values journal as
            # omitted and their producers simply re-run on resume.
            encoded = json.dumps(task.result)
            if (len(encoded) <= self.RESULT_JOURNAL_CAP
                    and json.loads(encoded) == task.result):
                if (isinstance(task.result, dict)
                        and "__codec__" in task.result):
                    # a plain value of this shape would be dispatched to a
                    # result codec on replay and silently substituted —
                    # omit it so the producer re-runs instead (same guard
                    # philosophy as the {"__future__"} placeholder clash)
                    return {"result_omitted": True}
                return {"result": task.result}
        except (TypeError, ValueError):
            pass
        # last chance before omission: a registered spiller may be able to
        # journal it (array values from fused kernels running on the
        # SCALAR path land here — without the spill, resume would re-run
        # every DONE member of a fuse=False run)
        record = spill_journal_value(task.result, self._spill_dir_for(task))
        if record is not None:
            return {"result": record}
        return {"result_omitted": True}

    # -- stage / pipeline closure -----------------------------------------------#

    def _maybe_finalize_stage(self, pipe: Pipeline, stage: Stage,
                              sink: Optional[List[Any]] = None) -> None:
        if stage.state != st.STAGE_SCHEDULED:
            return
        if stage.pending_tasks != 0:
            return
        if stage.failed_tasks and self.on_task_failure == "fail_stage":
            self.svc.advance(stage, st.STAGE_FAILED, sink=sink)
            pipe.mark_stage_final(stage.uid)
            tel.counter("wfp_stage_closures_total", outcome="failed").inc()
            self._finalize_pipeline(pipe, failed=True, sink=sink)
            return
        self.svc.advance(stage, st.STAGE_DONE, sink=sink)
        pipe.mark_stage_final(stage.uid)
        tel.counter("wfp_stage_closures_total", outcome="done").inc()
        if stage.post_exec is not None:
            # adaptivity: the hook may append stages to the pipeline (the
            # append listener marks it dirty for Enqueue)
            try:
                stage.post_exec(stage, pipe)
            except Exception:  # noqa: BLE001 - user hook, never fatal
                self.component_errors.append(
                    f"post_exec[{stage.uid}]: {traceback.format_exc(limit=5)}")
        if pipe.completed:
            if not pipe.is_final:
                self._finalize_pipeline(pipe, sink=sink)
            return
        # wake Enqueue only when this closure actually exposed schedulable
        # work: under superstage scheduling the chain's downstream stages
        # are already in flight, and a dirty mark per link closure would
        # cost one full schedule-queue round trip per member per stage —
        # O(members × links) no-op passes on the chain hot path
        nxt = pipe.next_stage()
        if nxt is None:
            if pipe.completed and not pipe.is_final:
                # the cursor caught up through already-final stages
                self._finalize_pipeline(pipe, sink=sink)
        elif nxt.state == st.STAGE_INITIAL:
            self._mark_dirty(pipe.uid)  # next stage is ready to schedule

    def _finalize_pipeline(self, pipe: Pipeline,
                           failed: Optional[bool] = None,
                           sink: Optional[List[Any]] = None) -> None:
        if pipe.is_final:
            # under superstage scheduling a chain's downstream stages are
            # already in flight when fail_stage finalizes the pipeline;
            # their (failed) closures must not re-finalize it — the state
            # machine forbids FAILED->FAILED and the extra decrement would
            # corrupt the open-pipeline count
            return
        if failed is None:
            failed = (pipe.failed_tasks > 0
                      and self.on_task_failure == "fail_stage")
        to = st.PIPELINE_FAILED if failed else st.PIPELINE_DONE
        prefix = ((st.PIPELINE_SCHEDULING,)
                  if pipe.state == st.PIPELINE_INITIAL else ())
        self.svc.advance_seq(pipe, prefix + (to,), sink=sink)
        tel.counter("wfp_pipeline_closures_total",
                    outcome="failed" if failed else "done").inc()
        with self._lock:  # closures arrive under different pipeline locks
            self._open_pipelines -= 1
            if self._open_pipelines <= 0:
                self.done_event.set()
        if self.on_pipeline_final is not None:
            try:
                self.on_pipeline_final(pipe)
            except Exception:  # noqa: BLE001 - service hook, never fatal
                self.component_errors.append(
                    f"on_pipeline_final[{pipe.uid}]: "
                    f"{traceback.format_exc(limit=5)}")
