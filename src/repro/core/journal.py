"""Write-ahead journal: transactional state updates + resume (paper §II-B.4).

"All state updates in EnTK are transactional, hence any EnTK component that
fails can be restarted at runtime without losing information about ongoing
execution. In case of full failure, EnTK can reacquire upon restarting
information about the state of the execution up to the latest successful
transaction before the failure." — the journal is that mechanism. EnTK syncs
to disk and keeps hooks for an external database; we implement the disk path
(JSONL, append-only, explicit flush policy) plus replay.

Records:
  {"rec": "transition", "kind": "task|stage|pipeline", "uid", "name",
   "frm", "to", "t", ...extra}
  {"rec": "session", "event": "start|resume|end", "t", ...}

Replay returns the latest state per (kind, name) so a resumed AppManager can
skip completed tasks — resume is keyed on *names* (stable across process
restarts) rather than uids (which are session-scoped).
"""

from __future__ import annotations

import io
import json
import os
import threading
import time
from typing import Any, Dict, Optional, Tuple

from .exceptions import JournalCorruption


class Journal:
    """Append-only JSONL write-ahead journal.

    ``flush_every`` trades durability for throughput: 1 = flush every record
    (strict transactional), N = flush every N records plus on close. The
    Fig.-6 benchmark sweeps this to show the cost of strict durability.
    """

    def __init__(self, path: Optional[str], flush_every: int = 32) -> None:
        self.path = path
        self.flush_every = max(1, flush_every)
        self._lock = threading.Lock()
        self._since_flush = 0
        self._fh: Optional[io.TextIOWrapper] = None
        self.records_written = 0
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._fh = open(path, "a", encoding="utf-8")

    @property
    def enabled(self) -> bool:
        """True when a write-ahead file actually backs this journal."""
        return self._fh is not None

    # -- write ----------------------------------------------------------------#

    def append(self, record: Dict[str, Any]) -> None:
        if self._fh is None:
            return
        record.setdefault("t", time.time())
        line = json.dumps(record, separators=(",", ":"), default=str)
        with self._lock:
            self._fh.write(line + "\n")
            self.records_written += 1
            self._since_flush += 1
            if self._since_flush >= self.flush_every:
                self._fh.flush()
                self._since_flush = 0

    def transition(self, kind: str, uid: str, name: str, frm: str, to: str,
                   **extra: Any) -> None:
        rec = {"rec": "transition", "kind": kind, "uid": uid, "name": name,
               "frm": frm, "to": to}
        rec.update(extra)
        self.append(rec)

    def session(self, event: str, **extra: Any) -> None:
        rec = {"rec": "session", "event": event}
        rec.update(extra)
        self.append(rec)

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._since_flush = 0

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None

    # -- replay ---------------------------------------------------------------#

    @staticmethod
    def replay(path: str) -> Dict[str, Any]:
        """Replay a journal file.

        Returns ``{"state": {(kind, name): last_state}, "retries": {name: n},
        "results": {name: value}, "result_omitted": {name, ...},
        "sessions": [...], "records": n}``. ``results`` restores task return
        values recorded on DONE transitions (data-flow resume: consumers of
        a task completed in a previous session still find their inputs);
        ``result_omitted`` names DONE tasks whose value could not be
        journaled (not JSON-serializable) — the AppManager re-runs those on
        resume rather than hand their consumers a lost value. Truncated
        trailing lines (torn write at crash) are tolerated; any earlier
        corruption raises :class:`JournalCorruption`.
        """
        state: Dict[Tuple[str, str], str] = {}
        retries: Dict[str, int] = {}
        results: Dict[str, Any] = {}
        result_omitted: set = set()
        sessions = []
        n = 0
        if not os.path.exists(path):
            return {"state": state, "retries": retries, "results": results,
                    "result_omitted": result_omitted, "sessions": sessions,
                    "records": 0}
        with open(path, "r", encoding="utf-8") as fh:
            lines = fh.readlines()
        for i, line in enumerate(lines):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break  # torn final write: recover to previous transaction
                raise JournalCorruption(
                    f"{path}: undecodable record at line {i + 1}") from None
            n += 1
            if rec.get("rec") == "transition":
                key = (rec["kind"], rec.get("name") or rec["uid"])
                state[key] = rec["to"]
                # pilot_lost FAILED hops are infrastructure failures
                # (federation member death): journaled for the audit trail,
                # but they never consumed the task's retry budget, so they
                # must not be restored into it on resume either
                if (rec["kind"] == "task" and rec["to"] == "FAILED"
                        and not rec.get("pilot_lost")):
                    retries[key[1]] = retries.get(key[1], 0) + 1
                if rec["kind"] == "task" and rec["to"] == "DONE":
                    # results ride the DONE record; a resumed-DONE replayed
                    # in a later session carries none — keep the last one
                    # actually recorded rather than clearing it
                    if "result" in rec:
                        results[key[1]] = rec["result"]
                        result_omitted.discard(key[1])
                    elif rec.get("result_omitted"):
                        result_omitted.add(key[1])
            elif rec.get("rec") == "session":
                sessions.append(rec)
        return {"state": state, "retries": retries, "results": results,
                "result_omitted": result_omitted, "sessions": sessions,
                "records": n}
