"""Write-ahead journal: transactional state updates + resume (paper §II-B.4).

"All state updates in EnTK are transactional, hence any EnTK component that
fails can be restarted at runtime without losing information about ongoing
execution. In case of full failure, EnTK can reacquire upon restarting
information about the state of the execution up to the latest successful
transaction before the failure." — the journal is that mechanism. EnTK syncs
to disk and keeps hooks for an external database; we implement the disk path
(JSONL, append-only, explicit flush policy) plus replay.

Crash consistency (chaos plane PR):

* every record carries a ``cs`` crc32 checksum over its canonical
  serialization, so a torn or bit-rotted tail is *detected*, not silently
  replayed as a shorter-but-valid JSON prefix;
* a torn/corrupt FINAL record is **truncated from disk** (with a warning)
  both on replay and on open-for-append — appending after a torn tail would
  otherwise concatenate the new record onto the partial line and corrupt
  both. Truncation is idempotent: a second replay sees identical bytes.
* FAILED and pipeline-final transition records are fsynced (not just
  flushed) regardless of ``flush_every`` — a host crash can delay progress
  records, but never lose terminal state.

Records:
  {"rec": "transition", "kind": "task|stage|pipeline", "uid", "name",
   "frm", "to", "t", ...extra, "cs": crc32}
  {"rec": "session", "event": "start|resume|end", "t", ..., "cs": crc32}

Replay returns the latest state per (kind, name) so a resumed AppManager can
skip completed tasks — resume is keyed on *names* (stable across process
restarts) rather than uids (which are session-scoped).
"""

from __future__ import annotations

import io
import json
import os
import threading
import time
import warnings
import zlib
from typing import Any, Dict, Optional, Tuple

from .exceptions import JournalCorruption

#: pipeline states whose journal record must hit the platter before the
#: caller proceeds (terminal state must survive a host crash)
_PIPELINE_FINAL = ("DONE", "FAILED", "CANCELED")


def _checksum(body: str) -> int:
    return zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF


def _seal(record: Dict[str, Any]) -> str:
    """Serialize a record with its ``cs`` checksum grafted on as the LAST
    key — replay pops it and re-serializes the remaining keys in their
    original order, so the check needs no canonicalization pass."""
    body = json.dumps(record, separators=(",", ":"), default=str)
    if body == "{}":
        return json.dumps({"cs": _checksum(body)}, separators=(",", ":"))
    return f'{body[:-1]},"cs":{_checksum(body)}}}'


def _verify(rec: Dict[str, Any]) -> bool:
    """Pop and check a parsed record's checksum. Records written before the
    checksum era (or hand-written fixtures) carry none and pass."""
    cs = rec.pop("cs", None)
    if cs is None:
        return True
    body = json.dumps(rec, separators=(",", ":"), default=str)
    return _checksum(body) == cs


def _line_ok(raw: bytes) -> bool:
    """One journal line decodes AND checksums (blank lines are fine)."""
    try:
        text = raw.decode("utf-8").strip()
    except UnicodeDecodeError:
        return False
    if not text:
        return True
    try:
        rec = json.loads(text)
    except json.JSONDecodeError:
        return False
    return isinstance(rec, dict) and _verify(rec)


class Journal:
    """Append-only JSONL write-ahead journal.

    ``flush_every`` trades durability for throughput: 1 = flush every record
    (strict transactional), N = flush every N records plus on close. The
    Fig.-6 benchmark sweeps this to show the cost of strict durability.
    ``fsync_critical`` (default on) additionally fsyncs FAILED and
    pipeline-final records the moment they are appended, regardless of
    ``flush_every`` — terminal state is never lost to a host crash.
    """

    def __init__(self, path: Optional[str], flush_every: int = 32,
                 fsync_critical: bool = True) -> None:
        self.path = path
        self.flush_every = max(1, flush_every)
        self.fsync_critical = fsync_critical
        self._lock = threading.Lock()
        self._since_flush = 0
        self._fh: Optional[io.TextIOWrapper] = None
        self.records_written = 0
        self.fsyncs = 0
        #: bytes of torn tail dropped before this session appended anything
        self.tail_recovered = 0
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            # appending onto a torn tail would concatenate the first new
            # record into the partial line, corrupting BOTH — recover first
            self.tail_recovered = self.recover_tail(path)
            self._fh = open(path, "a", encoding="utf-8")

    @property
    def enabled(self) -> bool:
        """True when a write-ahead file actually backs this journal."""
        return self._fh is not None

    # -- write ----------------------------------------------------------------#

    @staticmethod
    def _critical(record: Dict[str, Any]) -> bool:
        if record.get("rec") != "transition":
            return False
        to = record.get("to")
        return to == "FAILED" or (record.get("kind") == "pipeline"
                                  and to in _PIPELINE_FINAL)

    def append(self, record: Dict[str, Any]) -> None:
        if self._fh is None:
            return
        record.setdefault("t", time.time())
        line = _seal(record)
        critical = self.fsync_critical and self._critical(record)
        with self._lock:
            self._fh.write(line + "\n")
            self.records_written += 1
            self._since_flush += 1
            if critical:
                self._fh.flush()
                try:
                    os.fsync(self._fh.fileno())
                except OSError:  # non-seekable sink (pipe/FIFO test double)
                    pass
                self.fsyncs += 1
                self._since_flush = 0
            elif self._since_flush >= self.flush_every:
                self._fh.flush()
                self._since_flush = 0

    def transition(self, kind: str, uid: str, name: str, frm: str, to: str,
                   **extra: Any) -> None:
        rec = {"rec": "transition", "kind": kind, "uid": uid, "name": name,
               "frm": frm, "to": to}
        rec.update(extra)
        self.append(rec)

    def session(self, event: str, **extra: Any) -> None:
        rec = {"rec": "session", "event": event}
        rec.update(extra)
        self.append(rec)

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._since_flush = 0

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None

    # -- crash recovery -------------------------------------------------------#

    @staticmethod
    def recover_tail(path: str) -> int:
        """Drop a torn/corrupt FINAL record from the journal file.

        Returns the number of bytes truncated (0 when the tail is intact).
        Only the *last* record is ever repaired — an append-only writer can
        tear at most its final line; anything invalid earlier is real
        corruption and is left for :meth:`replay` to raise on. Idempotent:
        a repaired journal is byte-stable across repeated recoveries."""
        if not path or not os.path.exists(path):
            return 0
        total = 0
        while True:
            with open(path, "rb") as fh:
                data = fh.read()
            if not data:
                return total
            if not data.endswith(b"\n"):
                cut = data.rfind(b"\n") + 1    # unterminated tail: torn write
            else:
                start = data.rfind(b"\n", 0, len(data) - 1) + 1
                if _line_ok(data[start:len(data) - 1]):
                    return total
                cut = start                    # terminated but fails checksum
            dropped = len(data) - cut
            with open(path, "rb+") as fh:
                fh.truncate(cut)
            warnings.warn(
                f"{path}: dropped {dropped} bytes of torn journal tail "
                "(recovered to the previous transaction)", RuntimeWarning)
            total += dropped

    # -- replay ---------------------------------------------------------------#

    @staticmethod
    def replay(path: str) -> Dict[str, Any]:
        """Replay a journal file.

        Returns ``{"state": {(kind, name): last_state}, "retries": {name: n},
        "results": {name: value}, "result_omitted": {name, ...},
        "sessions": [...], "records": n}``. ``results`` restores task return
        values recorded on DONE transitions (data-flow resume: consumers of
        a task completed in a previous session still find their inputs);
        ``result_omitted`` names DONE tasks whose value could not be
        journaled (not JSON-serializable) — the AppManager re-runs those on
        resume rather than hand their consumers a lost value. A torn or
        checksum-failing trailing record (torn write at crash) is truncated
        from disk with a warning — replay-then-replay is byte-stable; any
        earlier corruption raises :class:`JournalCorruption`.
        """
        state: Dict[Tuple[str, str], str] = {}
        retries: Dict[str, int] = {}
        results: Dict[str, Any] = {}
        result_omitted: set = set()
        sessions = []
        n = 0
        if not os.path.exists(path):
            return {"state": state, "retries": retries, "results": results,
                    "result_omitted": result_omitted, "sessions": sessions,
                    "records": 0}
        Journal.recover_tail(path)
        with open(path, "r", encoding="utf-8") as fh:
            lines = fh.readlines()
        for i, line in enumerate(lines):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                if i == len(lines) - 1:
                    break  # torn final write: recover to previous transaction
                raise JournalCorruption(
                    f"{path}: undecodable record at line {i + 1}") from None
            if not isinstance(rec, dict) or not _verify(rec):
                if i == len(lines) - 1:
                    break
                raise JournalCorruption(
                    f"{path}: checksum mismatch at line {i + 1}")
            n += 1
            if rec.get("rec") == "transition":
                key = (rec["kind"], rec.get("name") or rec["uid"])
                state[key] = rec["to"]
                # pilot_lost FAILED hops are infrastructure failures
                # (federation member death): journaled for the audit trail,
                # but they never consumed the task's retry budget, so they
                # must not be restored into it on resume either
                if (rec["kind"] == "task" and rec["to"] == "FAILED"
                        and not rec.get("pilot_lost")):
                    retries[key[1]] = retries.get(key[1], 0) + 1
                if rec["kind"] == "task" and rec["to"] == "DONE":
                    # results ride the DONE record; a resumed-DONE replayed
                    # in a later session carries none — keep the last one
                    # actually recorded rather than clearing it
                    if "result" in rec:
                        results[key[1]] = rec["result"]
                        result_omitted.discard(key[1])
                    elif rec.get("result_omitted"):
                        result_omitted.add(key[1])
            elif rec.get("rec") == "session":
                sessions.append(rec)
        return {"state": state, "retries": retries, "results": results,
                "result_omitted": result_omitted, "sessions": sessions,
                "records": n}
