"""EnTK core: the paper's contribution as a composable Python/JAX library.

Public API (mirrors the paper's user-facing constructs):

* :class:`Task`, :class:`Stage`, :class:`Pipeline` — the PST model (§II-B.1)
* :class:`AppManager` — the execution entry point (§II-B.2)
* :func:`register_executable` — name a callable so workflows are resumable
* :class:`ResourceDescription` — pilot sizing

Example::

    from repro.core import AppManager, Pipeline, Stage, Task
    from repro.rts.base import ResourceDescription

    p = Pipeline("demo")
    s = Stage("s1")
    s.add_tasks([Task(executable="sleep://0.01") for _ in range(8)])
    p.add_stages(s)

    amgr = AppManager(resources=ResourceDescription(slots=4))
    amgr.workflow = [p]
    overheads = amgr.run()
"""

from . import states  # noqa: F401
from .appmanager import AppManager  # noqa: F401
from .broker import Broker  # noqa: F401
from .exceptions import (EnTKError, RTSFailure, StateTransitionError,  # noqa: F401
                         TaskFailure)
from .journal import Journal  # noqa: F401
from .profiler import Profiler  # noqa: F401
from .pst import (Pipeline, Stage, Task, WorkflowIndex,  # noqa: F401
                  register_executable)
from .results import STORE as RESULT_STORE, ResultStore  # noqa: F401
