"""Fault-tolerance policies: fault classes, retry/backoff, circuit breakers.

The stack grew two independent retry channels (paper §II-B.4 requires
fault tolerance; EnTK demonstrates it only for whole-pilot loss):

* **infra** — the pilot executing a task died (federation member failover,
  RTS restart). The task did nothing wrong: it is requeued unconditionally
  and the hop is journaled ``pilot_lost`` so resume never charges it.
* **task** — the task itself failed (nonzero exit, exception, non-finite
  output). Deterministic in expectation: each attempt consumes the task's
  retry budget.

:class:`RetryPolicy` names that split, makes both budgets explicit, and adds
exponential backoff with **deterministic** jitter (keyed hash of seed × task
× attempt — a chaos-seeded run replays the exact same schedule). The default
policy reproduces the historical behaviour bit-for-bit: task budget =
``task.max_retries`` (charged), infra unlimited (uncharged), zero backoff.

:class:`CircuitBreaker` / :class:`BreakerBoard` consume per-(kernel, tier)
failure outcomes so the JaxRTS trips the degrade ladder (composed → fused →
scalar) *proactively* instead of rediscovering a bad tier on every dispatch,
and re-closes after a probation window via a single half-open probe.
"""

from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .. import telemetry as tel

#: fault classes (the RetryPolicy budget key)
INFRA = "infra"    # pilot/member/RTS loss — not the task's fault
TASK = "task"      # the task's own failure — charged against its budget

#: telemetry families
RETRY_TOTAL = "retry_total"                        # {fault_class}
BREAKER_TRANSITIONS = "breaker_transitions_total"  # {kernel, tier, to}
BREAKER_SHORTCIRCUITS = "breaker_short_circuits_total"  # {kernel, tier}


def classify(msg: Dict[str, Any]) -> str:
    """Fault class of a failed completion message (Dequeue side)."""
    return INFRA if msg.get("pilot_lost") else TASK


def keyed_uniform(seed: int, *key: Any) -> float:
    """Deterministic uniform [0, 1) from a seed and a structured key.

    Order-independent across threads: the value depends only on the key,
    never on arrival order — the property that makes a seeded chaos run
    (and a jittered retry schedule) reproducible under concurrency."""
    h = hashlib.sha256(
        ":".join([str(seed)] + [str(k) for k in key]).encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclass
class RetryPolicy:
    """Per-task retry budgets and backoff, keyed by fault class.

    ``max_task_retries`` of ``None`` defers to each task's own
    ``max_retries`` (the historical contract); ``max_infra_retries`` of
    ``None`` keeps infra requeues unlimited (failover must lose zero
    completions even for ``max_retries=0`` tasks). ``backoff_base=0``
    requeues immediately. ``deadline_s`` caps the total time a task may
    spend retrying, measured from its first failure.
    """

    max_task_retries: Optional[int] = None
    max_infra_retries: Optional[int] = None
    backoff_base: float = 0.0
    backoff_factor: float = 2.0
    backoff_max: float = 30.0
    jitter: float = 0.0           # ± fraction of the computed delay
    deadline_s: Optional[float] = None
    seed: int = 0

    def budget(self, task: Any, fault_class: str) -> Optional[int]:
        """Allowed retries for the class; None = unlimited."""
        if fault_class == INFRA:
            return self.max_infra_retries
        if self.max_task_retries is not None:
            return self.max_task_retries
        return getattr(task, "max_retries", 0)

    def should_retry(self, task: Any, fault_class: str, attempts: int,
                     first_failure_t: Optional[float] = None) -> bool:
        """True when attempt ``attempts + 1`` may run. ``attempts`` counts
        failures of this class already charged to the task."""
        if (self.deadline_s is not None and first_failure_t is not None
                and time.monotonic() - first_failure_t > self.deadline_s):
            return False
        budget = self.budget(task, fault_class)
        return budget is None or attempts < budget

    def delay(self, task_name: str, attempt: int) -> float:
        """Backoff before attempt ``attempt`` (1-based), with deterministic
        jitter keyed on (seed, task, attempt)."""
        if self.backoff_base <= 0:
            return 0.0
        d = min(self.backoff_max,
                self.backoff_base * self.backoff_factor ** max(0, attempt - 1))
        if self.jitter > 0:
            u = keyed_uniform(self.seed, "backoff", task_name, attempt)
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(0.0, d)


# --------------------------------------------------------------------------- #
# Circuit breakers
# --------------------------------------------------------------------------- #

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """One (kernel, tier) breaker over the degrade ladder.

    closed → open after ``failure_threshold`` failures inside ``window_s``;
    open → half-open after ``probation_s`` (one probe dispatch allowed);
    half-open → closed on probe success, → open on probe failure. The clock
    is injectable so probation is testable without sleeping."""

    def __init__(self, failure_threshold: int = 3, window_s: float = 30.0,
                 probation_s: float = 5.0, clock=time.monotonic) -> None:
        self.failure_threshold = max(1, failure_threshold)
        self.window_s = window_s
        self.probation_s = probation_s
        self._clock = clock
        self._lock = threading.Lock()
        self.state = CLOSED
        self._failures: list = []     # monotonic timestamps inside window
        self._opened_at = 0.0
        self._probing = False
        self.transitions: list = []   # [(to_state, t)] — the audit trail

    def _set(self, state: str) -> None:
        self.state = state
        self.transitions.append((state, self._clock()))

    def allow(self) -> bool:
        """May a dispatch use this tier right now?"""
        with self._lock:
            if self.state == CLOSED:
                return True
            now = self._clock()
            if self.state == OPEN and now - self._opened_at >= self.probation_s:
                self._set(HALF_OPEN)
                self._probing = True
                return True          # the single half-open probe
            if self.state == HALF_OPEN and not self._probing:
                self._probing = True
                return True
            return False

    def record(self, ok: bool) -> Optional[str]:
        """Record a dispatch outcome; returns the new state on transition."""
        with self._lock:
            now = self._clock()
            if self.state == HALF_OPEN:
                self._probing = False
                if ok:
                    self._failures.clear()
                    self._set(CLOSED)
                    return CLOSED
                self._opened_at = now
                self._set(OPEN)
                return OPEN
            if ok:
                return None
            self._failures.append(now)
            cutoff = now - self.window_s
            self._failures = [t for t in self._failures if t >= cutoff]
            if self.state == CLOSED \
                    and len(self._failures) >= self.failure_threshold:
                self._opened_at = now
                self._set(OPEN)
                return OPEN
            return None


class BreakerBoard:
    """Per-(kernel, tier) breakers with shared knobs + telemetry.

    ``allow(kernel, tier)`` is consulted at pack/compose time; ``record``
    at drain time. Tiers follow the execution ladder ("shard", "chain",
    "fused", "dag"); scalar execution is never gated — it is the floor the
    ladder degrades to. State transitions increment
    ``breaker_transitions_total{kernel, tier, to}`` and short-circuited
    dispatches ``breaker_short_circuits_total{kernel, tier}``."""

    def __init__(self, failure_threshold: int = 3, window_s: float = 30.0,
                 probation_s: float = 5.0, clock=time.monotonic,
                 registry: Optional[tel.MetricsRegistry] = None) -> None:
        self.failure_threshold = failure_threshold
        self.window_s = window_s
        self.probation_s = probation_s
        self._clock = clock
        self._registry = registry
        self._lock = threading.Lock()
        self._breakers: Dict[Tuple[str, str], CircuitBreaker] = {}

    def _counter(self, name: str, **labels: Any):
        reg = self._registry
        return (reg.counter(name, **labels) if reg is not None
                else tel.counter(name, **labels))

    def breaker(self, kernel: str, tier: str) -> CircuitBreaker:
        key = (kernel, tier)
        with self._lock:
            b = self._breakers.get(key)
            if b is None:
                b = CircuitBreaker(self.failure_threshold, self.window_s,
                                   self.probation_s, clock=self._clock)
                self._breakers[key] = b
            return b

    def allow(self, kernel: Optional[str], tier: str) -> bool:
        if kernel is None:
            return True
        ok = self.breaker(kernel, tier).allow()
        if not ok:
            self._counter(BREAKER_SHORTCIRCUITS,
                          kernel=kernel, tier=tier).inc()
        return ok

    def record(self, kernel: Optional[str], tier: str, ok: bool) -> None:
        if kernel is None:
            return
        moved = self.breaker(kernel, tier).record(ok)
        if moved is not None:
            self._counter(BREAKER_TRANSITIONS,
                          kernel=kernel, tier=tier, to=moved).inc()

    def states(self) -> Dict[Tuple[str, str], str]:
        with self._lock:
            return {k: b.state for k, b in self._breakers.items()}
