"""Overhead profiler matching the paper's measurement taxonomy (§IV-A.2).

The paper decomposes the non-task time into named overheads:

* **EnTK Setup Overhead** — messaging infrastructure + component instantiation
  + description validation.
* **EnTK Management Overhead** — processing the application, translating tasks
  to/from RTS objects, communicating PST entities and control messages.
* **EnTK Tear-Down Overhead** — canceling components + shutting down messaging.
* **RTS Overhead** — RTS submission/management time.
* **RTS Tear-Down Overhead** — RTS cancellation/shutdown.
* **Data Staging Time** and **Task Execution Time**.

Components call ``prof.begin(cat)/prof.end(cat)`` (or the ``measure``
context manager) around the corresponding code paths; the benchmark harness
then reads ``prof.totals()`` to emit one row per experiment, exactly mirroring
Fig. 7's stacked bars.
"""

from __future__ import annotations

import threading
import time
from collections import defaultdict
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional, Tuple

# Canonical category names (keys of the Fig.-7 stacks).
ENTK_SETUP = "entk_setup"
ENTK_MANAGEMENT = "entk_management"
ENTK_TEARDOWN = "entk_teardown"
RTS_OVERHEAD = "rts_overhead"
RTS_TEARDOWN = "rts_teardown"
DATA_STAGING = "data_staging"
TASK_EXECUTION = "task_execution"

CATEGORIES = (
    ENTK_SETUP, ENTK_MANAGEMENT, ENTK_TEARDOWN,
    RTS_OVERHEAD, RTS_TEARDOWN, DATA_STAGING, TASK_EXECUTION,
)


class Profiler:
    """Thread-safe accumulating profiler.

    ``clock`` is injectable so the SimulatedRTS can report virtual durations
    for task execution / staging while real (wall) time is used for toolkit
    overheads — the same split the paper uses when it separates RTS-side from
    EnTK-side measures.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._totals: Dict[str, float] = defaultdict(float)
        self._counts: Dict[str, int] = defaultdict(int)
        self._open: Dict[Tuple[str, int], float] = {}
        self._events: List[Tuple[str, float]] = []

    # -- interval API -----------------------------------------------------#

    def begin(self, category: str) -> None:
        key = (category, threading.get_ident())
        with self._lock:
            self._open[key] = time.perf_counter()

    def end(self, category: str) -> float:
        key = (category, threading.get_ident())
        now = time.perf_counter()
        with self._lock:
            t0 = self._open.pop(key, None)
            if t0 is None:
                return 0.0
            dt = now - t0
            self._totals[category] += dt
            self._counts[category] += 1
            return dt

    @contextmanager
    def measure(self, category: str) -> Iterator[None]:
        self.begin(category)
        try:
            yield
        finally:
            self.end(category)

    def add(self, category: str, seconds: float, count: int = 1) -> None:
        """Directly accumulate a duration (used for virtual-time categories)."""
        with self._lock:
            self._totals[category] += seconds
            self._counts[category] += count

    def event(self, name: str, t: Optional[float] = None) -> None:
        with self._lock:
            self._events.append((name, time.time() if t is None else t))

    # -- reads --------------------------------------------------------------#

    def totals(self) -> Dict[str, float]:
        with self._lock:
            return dict(self._totals)

    def counts(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._counts)

    def events(self) -> List[Tuple[str, float]]:
        with self._lock:
            return list(self._events)

    def report(self) -> str:
        totals = self.totals()
        lines = ["category,seconds"]
        for cat in CATEGORIES:
            lines.append(f"{cat},{totals.get(cat, 0.0):.6f}")
        for cat in sorted(set(totals) - set(CATEGORIES)):
            lines.append(f"{cat},{totals[cat]:.6f}")
        return "\n".join(lines)
