"""ExecManager: the workload-management component (paper §II-B.2/3).

Subcomponents (threads):

* **Rmgr** — acquires/releases resources (starts the pilot) via the RTS.
* **Emgr** — pulls tasks from the ``pending`` queue into a submission
  backlog and translates them into RTS submissions. Submission is
  **slot-aware**: each round asks the RTS for its free-slot count
  (:meth:`~repro.rts.base.RTS.free_slots`) and packs the backlog into the
  available capacity with largest-fit backfill keyed on ``task.slots``, so
  wide tasks stop head-of-line-blocking narrow ones and the RTS queue never
  balloons. A starvation guard falls back to strict FIFO draining when the
  backlog head has been passed over too often, so no task waits forever.
  The loop is event-driven: it blocks on the pending queue and is kicked
  awake by completions (slots freed), pilot resizes and RTS restarts.
* **RTSCallback** — receives completion events from the RTS and pushes them
  onto the ``done`` queue (and kicks the Emgr: capacity changed).
* **Heartbeat** — probes RTS liveness; on failure the AppManager tears the
  RTS down, starts a fresh instance and resubmits exactly the lost in-flight
  tasks (black-box RTS fault tolerance, §II-B.4).
* **Watchdog** (beyond paper; required at 10³+ nodes) — straggler
  mitigation via speculative re-execution: a task that exceeds
  ``straggler_factor ×`` its expected duration is cloned; the first attempt
  to finish wins, the loser is canceled and its completion deduplicated.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
import traceback
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

from .. import telemetry as tel
from . import states as st
from .broker import Broker
from .profiler import ENTK_MANAGEMENT, RTS_OVERHEAD, RTS_TEARDOWN, Profiler
from .pst import Task, WorkflowIndex, resolve_executable
from .state_service import StateService
from .wfprocessor import DONE_QUEUE, PENDING_QUEUE
from ..rts.base import RTS, ResourceDescription, TaskCompletion

#: Task.tags keys of a fused-chain link / fused-DAG node (literals: the core
#: never imports the fusion package; the api compiler stamps them, the
#: JaxRTS consumes them).
CHAIN_TAG = "_fusion_chain"
DAG_TAG = "_fusion_dag"


def _flow_tag(task: Task) -> Optional[dict]:
    """The task's chain OR DAG tag (a task is on at most one flow). Both
    carry ``c``/``k``/``m`` and an ``ss`` superstage extent; a DAG tag
    additionally carries ``w`` (its node's full width), which is what the
    readiness rule keys on."""
    tag = task.tags.get(CHAIN_TAG)
    if tag is None:
        tag = task.tags.get(DAG_TAG)
    return tag if isinstance(tag, dict) else None


class _Lane:
    """One tenant's private slice of the submission backlog (fair share).

    Everything the single-tenant packer keeps as instance state that must
    not leak between tenants lives here: the width buckets, the starvation
    guard's skip count, and the chain-hold bookkeeping (``_chain_ready_locked``
    clears the released set when a lane holds no chains — per-lane state
    keeps one tenant's chain-free round from wiping another's valve
    release). ``deficit`` is the weighted deficit-round-robin credit in
    MEMBERS; an atomic whole-group drain may overdraw it, and the debt
    carries — the oversized-packet rule that stops a 1M-member sweep from
    starving interactive tenants."""

    __slots__ = ("backlog", "head_skips", "has_chain_backlog",
                 "chain_released", "deficit")

    def __init__(self) -> None:
        self.backlog: Dict[int, Deque] = {}
        self.head_skips = 0
        self.has_chain_backlog = False
        self.chain_released: set = set()
        self.deficit = 0.0


class ExecManager:
    def __init__(
        self,
        broker: Broker,
        svc: StateService,
        prof: Profiler,
        rts_factory: Callable[[], RTS],
        resources: ResourceDescription,
        index: WorkflowIndex,
        heartbeat_interval: float = 0.5,
        max_rts_restarts: int = 3,
        straggler_factor: float = 0.0,  # 0 disables speculation
        straggler_min_seconds: float = 1.0,
        speculation_min_samples: int = 64,
        starvation_limit: int = 8,
    ) -> None:
        self.broker = broker
        self.svc = svc
        self.prof = prof
        self.rts_factory = rts_factory
        self.resources = resources
        self.index = index
        self.heartbeat_interval = heartbeat_interval
        self.max_rts_restarts = max_rts_restarts
        self.straggler_factor = straggler_factor
        self.straggler_min_seconds = straggler_min_seconds
        # quantile-driven speculation (ROADMAP 4c): once a kernel has this
        # many dispatch-latency samples, the watchdog thresholds at
        # p99 × straggler_factor instead of the fixed duration_hint
        self.speculation_min_samples = speculation_min_samples
        self.starvation_limit = starvation_limit

        self.rts: Optional[RTS] = None
        self.rts_restarts = 0
        self._submitted: Dict[str, Task] = {}   # uid -> task, in RTS custody
        # Submission backlog: pulled from the pending queue, awaiting free
        # slots. Lives on the instance (not the loop) so an Emgr-thread crash
        # + restart does not strand tasks. Stored as width buckets (one FIFO
        # deque of (seq, task) per task.slots value) so each submit round
        # costs O(batch + distinct widths), not O(backlog log backlog).
        self._backlog: Dict[int, Deque] = {}
        self._backlog_uids: set = set()
        self._backlog_seq = itertools.count()
        self._head_skips = 0                    # rounds the head was passed over
        # chain fusion (see _chain_ready_locked): a chain link may only be
        # submitted once its member's terminal link is visible, so the RTS
        # always receives whole member chains and orders the links itself
        self._has_chain_backlog = False
        self._chain_holding = False
        self._chain_held_ids: set = set()
        self._chain_released: set = set()
        self._chain_stalls = 0
        # Fair share (serving mode, opt-in via set_fair_share): tasks are
        # bucketed into per-tenant lanes keyed on tags["_tenant"] and packed
        # by weighted deficit-round-robin; None keeps the classic
        # single-backlog path byte-identical.
        self._fair_policy = None
        self._lanes: Dict[str, _Lane] = {}
        self._lane_cursor = 0
        self.fair_quantum = 256     # DRR credit (members) per visit per weight
        self._picked_slots = 0      # slots charged by the last pick round
        self._spec_of: Dict[str, str] = {}      # clone uid -> original uid
        self._spec_for: Dict[str, str] = {}     # original uid -> clone uid
        self._speculated: set = set()           # originals already cloned
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._emgr_thread: Optional[threading.Thread] = None
        self._hb_thread: Optional[threading.Thread] = None
        self._wd_thread: Optional[threading.Thread] = None
        self.emgr_crash_hook: Optional[Callable[[], None]] = None
        self.component_errors: List[str] = []
        self.speculations = 0
        self.speculation_wins = 0
        self.speculations_from_quantile = 0   # thresholded at measured p99
        self.speculations_from_hint = 0       # cold-start duration_hint path
        self._kernel_cache: Dict = {}         # payload key -> telemetry label
        # Observability for the no-busy-wait tests: wakeups only happen on
        # pending messages or capacity kicks, never on a poll timer.
        self.emgr_wakeups = 0
        self.submit_rounds = 0

    # -- Rmgr ------------------------------------------------------------------#

    def acquire_resources(self) -> None:
        with self.prof.measure(RTS_OVERHEAD):
            self.rts = self.rts_factory()
            self.rts.set_callback(self._rts_callback)
            if hasattr(self.rts, "set_capacity_callback"):
                # federation: member re-admission announces new capacity so
                # the backlog re-evaluates without polling
                self.rts.set_capacity_callback(self._on_capacity_change)
            pilot = self.rts.start(self.resources)
            # Record granted-not-requested: a backend may clamp (JaxRTS:
            # device inventory; federation: aggregate of member grants) and
            # reports the granted count through the pilot description instead
            # of mutating the caller's ResourceDescription in place.
            granted = getattr(getattr(pilot, "description", None), "slots",
                              None)
            if isinstance(granted, int) and granted > 0:
                self.resources.slots = granted

    def _on_capacity_change(self) -> None:
        # same contract as the completion kick: only wake the Emgr when it
        # actually holds tasks back for capacity (_backlog_uids spans the
        # classic backlog AND the fair-share tenant lanes)
        if self._backlog_uids:
            self.broker.kick(PENDING_QUEUE)

    def release_resources(self) -> None:
        if self.rts is not None:
            with self.prof.measure(RTS_TEARDOWN):
                self.rts.stop()

    def resize(self, slots: int) -> None:
        """Elastic scaling passthrough; wakes the Emgr (capacity changed).
        ``resources.slots`` records what the RTS actually granted — a
        backend may clamp (JaxRTS: device inventory), and an unclamped
        value here would break the Emgr's pilot-idle starvation escape."""
        if self.rts is not None:
            self.resources.slots = self.rts.resize(slots)
            self.broker.kick(PENDING_QUEUE)

    # -- lifecycle ----------------------------------------------------------#

    def start(self) -> None:
        self._stop.clear()
        self.start_emgr()
        self.start_heartbeat()
        if self.straggler_factor > 0:
            self.start_watchdog()

    def start_emgr(self) -> None:
        self._emgr_thread = threading.Thread(
            target=self._guarded, args=(self._emgr_loop, "emgr"),
            daemon=True, name="em-emgr")
        self._emgr_thread.start()

    def start_heartbeat(self) -> None:
        self._hb_thread = threading.Thread(
            target=self._guarded, args=(self._heartbeat_loop, "heartbeat"),
            daemon=True, name="em-heartbeat")
        self._hb_thread.start()

    def start_watchdog(self) -> None:
        self._wd_thread = threading.Thread(
            target=self._guarded, args=(self._watchdog_loop, "watchdog"),
            daemon=True, name="em-watchdog")
        self._wd_thread.start()

    def stop(self) -> None:
        self._stop.set()
        self.broker.kick(PENDING_QUEUE)
        for t in (self._emgr_thread, self._hb_thread, self._wd_thread):
            if t is not None:
                t.join(timeout=5.0)
        self._emgr_thread = self._hb_thread = self._wd_thread = None
        self.release_resources()

    def threads_alive(self) -> Dict[str, bool]:
        """Liveness of every ExecManager thread, so the AppManager's
        component-restart logic can observe (and heal) any of them dying."""
        alive = {
            "emgr": bool(self._emgr_thread and self._emgr_thread.is_alive()),
            "heartbeat": bool(self._hb_thread and self._hb_thread.is_alive()),
        }
        if self.straggler_factor > 0:
            alive["watchdog"] = bool(self._wd_thread
                                     and self._wd_thread.is_alive())
        return alive

    def _guarded(self, fn: Callable[[], None], name: str) -> None:
        try:
            fn()
        except Exception:  # noqa: BLE001
            self.component_errors.append(
                f"{name}: {traceback.format_exc(limit=5)}")

    # -- Emgr ------------------------------------------------------------------#

    def _emgr_loop(self) -> None:
        while not self._stop.is_set():
            msgs = self.broker.get_many(PENDING_QUEUE, 128, timeout=None,
                                        abort=self._stop)
            if self._stop.is_set():
                return
            if self.emgr_crash_hook is not None:
                self.emgr_crash_hook()
            self.emgr_wakeups += 1
            if msgs:
                t0 = time.perf_counter()
                with self._lock:
                    for tag, uid in msgs:
                        task = self.index.task(uid)
                        # SUBMITTING is advanced at submission time (one
                        # coalesced SUBMITTING→SUBMITTED hop per task);
                        # backlogged tasks stay SCHEDULED
                        if (task is not None and not task.is_final
                                and uid not in self._backlog_uids
                                and uid not in self._submitted):
                            if self._fair_policy is not None:
                                lane = self._lane_for(task)
                                lane.backlog.setdefault(
                                    task.slots, deque()).append(
                                        (next(self._backlog_seq), task))
                                self._backlog_uids.add(uid)
                                if (CHAIN_TAG in task.tags
                                        or DAG_TAG in task.tags):
                                    lane.has_chain_backlog = True
                                continue
                            self._backlog.setdefault(
                                task.slots, deque()).append(
                                    (next(self._backlog_seq), task))
                            self._backlog_uids.add(uid)
                            if (CHAIN_TAG in task.tags
                                    or DAG_TAG in task.tags):
                                # arms the whole-chain/DAG hand-off
                                # machinery; flow-free workloads never pay
                                # its scan
                                self._has_chain_backlog = True
                self.broker.ack_many(PENDING_QUEUE, [t for t, _ in msgs])
                self.prof.add(ENTK_MANAGEMENT, time.perf_counter() - t0)
            # quiescent = a kick-only wakeup: while pending messages are
            # still streaming in, a held chain is simply incomplete, not
            # stalled — only kick wakeups may advance the anti-stall valve
            self._submit_ready(quiescent=not msgs)

    def _submit_ready(self, quiescent: bool = True) -> None:
        """Pack backlog tasks into the RTS's free slots and submit them.

        Against a federated RTS (one exposing :meth:`member_slots`) the
        packer is placement-aware: largest-fit backfill *within* each member,
        least-loaded spill *across* members, hard ``task.backend`` affinity,
        and the starvation guard preserved federation-wide. Each placed task
        carries its member in ``task.tags['_fed_member']`` so the federation
        routes it without re-deciding."""
        rts = self.rts
        if rts is None:
            return
        try:
            fusion = rts.supports_fusion()
        except Exception:  # noqa: BLE001 - dying RTS: heartbeat handles it
            fusion = False
        member_slots = getattr(rts, "member_slots", None)
        if member_slots is not None:
            try:
                slots_map = member_slots()
            except Exception:  # noqa: BLE001 - dying RTS: heartbeat handles it
                return
            known = getattr(rts, "member_names", lambda: list(slots_map))()
            # whole-group pinning is only sound on members that actually
            # batch fused groups; a federation names them, a plain RTS that
            # supports fusion batches everywhere it places
            fuse_members = getattr(rts, "fusion_members", None)
            fusing = (set(fuse_members()) if fuse_members is not None
                      else (set(known) if fusion else set()))
            with self._lock:
                if self._fair_policy is not None:
                    # fair share + federation is not packed per-tenant this
                    # release: the lanes fold back into the classic backlog
                    # and the placement-aware packer runs as before
                    self._merge_lanes_locked()
                placements = self._pick_batch_federated_locked(
                    slots_map, set(known), fusing=fusing)
                batch = []
                for name, task in placements:
                    task.tags["_fed_member"] = name
                    self._submitted[task.uid] = task
                    batch.append(task)
        else:
            try:
                free = rts.free_slots()
            except Exception:  # noqa: BLE001 - dying RTS: heartbeat handles it
                return
            with self._lock:
                if self._fair_policy is not None:
                    batch = self._pick_batch_fair_locked(free, fusion=fusion)
                else:
                    batch = self._pick_batch_locked(free, fusion=fusion)
                for task in batch:
                    self._submitted[task.uid] = task
                self._chain_valve_locked(bool(batch), quiescent)
        if not batch:
            return
        self.submit_rounds += 1
        t1 = time.perf_counter()
        # SUBMITTED before the actual hand-off: an instantly-completing task
        # must never race its DONE transition past SUBMITTING. If submit()
        # fails, the heartbeat restart path resubmits from self._submitted.
        # The advance chain runs under self._lock: AppManager.cancel takes
        # the same lock, so a concurrent CANCELED can never interleave with
        # (or be overwritten by) the SUBMITTING→SUBMITTED hops.
        now = time.time()
        sink: List = []
        submittable: List[Task] = []
        with self._lock:
            for task in batch:
                try:
                    self.svc.advance_seq(task, (st.SUBMITTING, st.SUBMITTED),
                                         transact=False, sink=sink)
                except Exception:  # noqa: BLE001 - canceled concurrently
                    self._submitted.pop(task.uid, None)
                    continue
                task.submitted_at = now
                submittable.append(task)
        self.svc.flush(sink)  # publish before the RTS can complete anything
        if not submittable:
            return
        with tel.span("emgr.submit", "emgr", tasks=len(submittable)):
            rts.submit(submittable)
        tel.counter("emgr_submit_rounds_total").inc()
        tel.counter("emgr_submitted_tasks_total").inc(len(submittable))
        self.prof.add(RTS_OVERHEAD, time.perf_counter() - t1)

    def _prune_fronts_locked(self) -> None:
        """Drop finalized (e.g. canceled-while-waiting) tasks from bucket
        fronts and delete empty buckets; interior finals are skipped lazily
        when the backfill reaches them."""
        for width in list(self._backlog):
            dq = self._backlog[width]
            while dq and dq[0][1].is_final:
                _, stale = dq.popleft()
                self._backlog_uids.discard(stale.uid)
            if not dq:
                del self._backlog[width]

    def _pop_head_locked(self, head: Task) -> None:
        """Remove ``head`` from the front of its width bucket (it is always
        a bucket front: heads are picked from fronts only)."""
        dq = self._backlog[head.slots]
        dq.popleft()
        if not dq:
            del self._backlog[head.slots]
        self._backlog_uids.discard(head.uid)

    def _head_locked(self) -> Optional[Task]:
        """The globally oldest live backlog task (min seq over fronts)."""
        best = None
        for dq in self._backlog.values():
            seq, task = dq[0]
            if best is None or seq < best[0]:
                best = (seq, task)
        return best[1] if best else None

    # -- whole-chain hand-off (chain fusion) ----------------------------------#

    def _chain_ready_locked(self) -> Optional[set]:
        """Chain ids whose backlog fragment is submittable as one piece.

        The superstage scheduler hands a chain's stages off in one batched
        pending publish, but the broker delivers it in bounded chunks — so
        a pack round can see link 0 of members whose links 1..L-1 are
        still in the queue. Submitting such a fragment would hand the RTS
        a downstream link later, mid-flight, racing the result-store
        routing of its inputs. The rule: a chain is held until EVERY
        member present in the backlog has its *fragment-terminal* link —
        the highest link the superstage co-published, stamped as ``ss`` on
        the tag — there too (FIFO delivery then guarantees all the links
        in between as well), at which point the whole-chain drain submits
        every member's full link range in one ``rts.submit``, and the RTS
        owns the ordering. Tasks without an ``ss`` stamp were never
        co-published (mixed stage, federation, gated continuation): their
        stages flow one at a time, so they are never held. Returns None
        when the backlog holds no chain (chain-free workloads skip the
        scan entirely).
        """
        if not self._has_chain_backlog:
            return None
        seen: set = set()
        waiting: Dict[str, set] = {}
        arrived: Dict[str, set] = {}
        dag_ids: set = set()
        dag_width: Dict[str, int] = {}   # DAG id -> terminal node width
        for dq in self._backlog.values():
            for _, task in dq:
                tag = _flow_tag(task)
                if tag is None:
                    continue
                c = tag.get("c")
                seen.add(c)
                if "w" in tag:
                    dag_ids.add(c)
                ss = tag.get("ss")
                if not isinstance(ss, int):
                    continue  # never co-published: nothing to wait for
                if tag.get("k") == ss:
                    arrived.setdefault(c, set()).add(tag.get("m"))
                    if isinstance(tag.get("w"), int):
                        dag_width[c] = tag["w"]
                else:
                    waiting.setdefault(c, set()).add(tag.get("m"))
        if not seen:
            # the last chain drained: stop paying the scan until the next
            # chain-tagged task enters the backlog
            self._has_chain_backlog = False
            self._chain_released.clear()
            return None
        # a valve release is one-shot: it covers exactly the stuck fragment
        # that tripped it — once that fragment leaves the backlog, later
        # fragments of the same chain get the normal hold + custody veto
        # again (and the set cannot grow across adaptive rounds)
        self._chain_released &= seen
        # custody veto: while ANY link of a chain is submitted-but-
        # unfinished, later fragments of that chain (a retried member, a
        # straggling broker chunk) must wait — submitting them would race
        # the in-flight links' result routing exactly like a split fragment
        busy = set()
        for task in self._submitted.values():
            tag = _flow_tag(task)
            if tag is not None:
                busy.add(tag.get("c"))
        ready = set()
        for c in set(waiting) | set(arrived):
            if c in busy:
                continue
            if c in dag_ids:
                # count-based rule for DAGs: node widths change across a
                # fan-in (k members -> 1 reducer -> k members), so the
                # chains' member-subset rule cannot transfer. The whole
                # TERMINAL node being in the backlog implies — by FIFO
                # delivery of the superstage's single batched publish —
                # that every earlier node's task arrived too.
                if len(arrived.get(c, ())) >= dag_width.get(c, 1 << 30):
                    ready.add(c)
            elif waiting.get(c, set()) <= arrived.get(c, set()):
                ready.add(c)
        return ready

    def _chain_held_locked(self, task: Task, chain_ready: set) -> bool:
        tag = _flow_tag(task)
        if tag is None:
            return False
        if not isinstance(tag.get("ss"), int):
            return False  # never superstaged: stage gating orders it
        cid = tag.get("c")
        if cid in chain_ready or cid in self._chain_released:
            return False
        self._chain_holding = True
        self._chain_held_ids.add(cid)
        return True

    def _take_locked(self, width: int, batch: List[Task],
                     remaining: int, fusion: bool = False,
                     chain_ready: Optional[set] = None) -> int:
        """Move fitting live tasks of one width bucket into ``batch``.

        Against a fusion-capable RTS, taking a task that carries a
        ``_fusion_group`` tag drains every *consecutive* same-group task in
        the bucket along with it, charging the group's slots ONCE: the RTS
        executes the whole group as batched dispatches on one member-width
        device lease, so per-member slot accounting here would throttle
        submission to scalar speed — the opposite of what fusion buys.
        A ``_fusion_chain`` link additionally drains its whole chain (every
        link's group, one charge) and is held back while its member's
        chain is still incomplete (see :meth:`_chain_ready_locked`).
        A group the RTS plans to execute as an SPMD *mesh* dispatch is
        charged the whole mesh instead (:meth:`RTS.planned_group_slots`) —
        one sharded carrier really does occupy every mesh device.
        """
        dq = self._backlog.get(width)
        while dq and width <= remaining:
            _, task = dq[0]
            if task.is_final:
                dq.popleft()
                self._backlog_uids.discard(task.uid)
                continue  # lazily pruned
            if (chain_ready is not None
                    and self._chain_held_locked(task, chain_ready)):
                break  # strict FIFO within the width: hold the bucket here
            dq.popleft()
            self._backlog_uids.discard(task.uid)
            batch.append(task)
            remaining -= width
            if fusion:
                before = len(batch)
                self._drain_group_locked(dq, task, batch.append)
                remaining -= self._group_surcharge(
                    1 + len(batch) - before, width)
        if dq is not None and not dq:
            del self._backlog[width]
        return remaining

    def _group_surcharge(self, n_members: int, width: int) -> int:
        """Slots beyond the historical one-member charge for a drained
        fused group: a sharded carrier leases the whole mesh, so the
        packer must not backfill other work into those slots."""
        if n_members < 2 or self.rts is None:
            return 0
        try:
            planned = int(self.rts.planned_group_slots(n_members, width))
        except Exception:  # noqa: BLE001 - advisory hook only
            return 0
        return max(0, planned - width)

    def _drain_group_locked(self, dq: Optional[Deque], first: Task,
                            take: Callable[[Task], None]) -> None:
        """Pop every consecutive task sharing ``first``'s fusion group —
        or, for a chain link, EVERY task of ``first``'s chain anywhere in
        the bucket — into ``take`` (lazily pruning finals) WITHOUT
        charging slots: the run rides the batched dispatches its first
        member already paid for.

        The chain drain deliberately ignores adjacency: two chains' (or a
        chain's and other work's) tasks may interleave in one bucket, and
        leaving a ready chain's tail behind would submit it as a separate
        fragment in a later round — racing the links already in flight.
        Non-chain tasks keep their relative FIFO order.
        """
        group = first.tags.get("_fusion_group")
        ftag = _flow_tag(first)
        chain = ftag.get("c") if ftag is not None else None
        if chain is not None:
            if not dq:
                return
            kept: Deque = deque()
            while dq:
                entry = dq.popleft()
                _, nxt = entry
                if nxt.is_final:
                    self._backlog_uids.discard(nxt.uid)
                    continue
                ntag = _flow_tag(nxt)
                if ntag is not None and ntag.get("c") == chain:
                    self._backlog_uids.discard(nxt.uid)
                    take(nxt)
                else:
                    kept.append(entry)
            dq.extend(kept)
            return
        if group is None:
            return
        while dq:
            _, nxt = dq[0]
            if nxt.is_final:
                dq.popleft()
                self._backlog_uids.discard(nxt.uid)
                continue
            ntag = _flow_tag(nxt)
            if ntag is not None or nxt.tags.get("_fusion_group") != group:
                return
            dq.popleft()
            self._backlog_uids.discard(nxt.uid)
            take(nxt)

    def _pick_batch_locked(self, free: Optional[int],
                           fusion: bool = False) -> List[Task]:
        """Largest-fit backfill of the backlog into ``free`` slots.

        ``free is None`` means the RTS does not report capacity (e.g. the
        SimulatedRTS's virtual clock makes wallclock capacity meaningless):
        drain the backlog FIFO, as the pre-slot-aware Emgr did.

        Fairness: if the FIFO head was passed over ``starvation_limit``
        times, it is placed FIRST on the round it fits, and while it does
        not fit nothing younger may jump it (conservative backfill). A head
        wider than the whole idle pilot is submitted anyway — the RTS, not
        the Emgr, owns that error.
        """
        self._prune_fronts_locked()
        self._chain_holding = False
        self._chain_held_ids = set()
        self._picked_slots = 0
        if not self._backlog:
            return []
        if free is None:
            # full FIFO drain: merge the width buckets back into seq order
            merged = heapq.merge(*self._backlog.values())
            batch = [task for _, task in merged if not task.is_final]
            self._backlog.clear()
            self._backlog_uids.clear()
            return batch
        chain_ready = self._chain_ready_locked() if fusion else None
        head = self._head_locked()
        if head is None:
            return []
        batch: List[Task] = []
        remaining = free
        if head.slots > free:
            pilot_idle = free >= max(1, self.resources.slots)
            if pilot_idle and not self._submitted:
                # the head can never fit: hand it over, let the RTS decide
                self._pop_head_locked(head)
                self._head_skips = 0
                self._picked_slots = free
                return [head]
            if self._head_skips >= self.starvation_limit:
                return []  # hold everything: drain until the head fits
        elif self._head_skips >= self.starvation_limit:
            if (chain_ready is not None
                    and self._chain_held_locked(head, chain_ready)):
                # a held chain link is starved by design: its missing links
                # are seconds (or one valve trip) away — never force a
                # partial chain past the hold
                return []
            # starved head goes first, then backfill with what still fits
            self._pop_head_locked(head)
            batch.append(head)
            remaining -= head.slots
            self._head_skips = 0
            if fusion:
                before = len(batch)
                self._drain_group_locked(
                    self._backlog.get(head.slots), head, batch.append)
                remaining -= self._group_surcharge(
                    1 + len(batch) - before, head.slots)
        for width in sorted(self._backlog, reverse=True):
            if remaining <= 0:
                break
            remaining = self._take_locked(width, batch, remaining,
                                          fusion=fusion,
                                          chain_ready=chain_ready)
        if not batch:
            return []
        self._picked_slots = free - remaining   # slot charge (group-aware)
        if any(t.uid == head.uid for t in batch):
            self._head_skips = 0
        else:
            self._head_skips += 1
        return batch

    # -- fair share (serving mode) ---------------------------------------------#

    def set_fair_share(self, policy) -> None:
        """Install a weighted fair-share policy (duck-typed: anything with
        ``weight(tenant) -> float``; see ``repro.serve.fair_share``). The
        backlog then packs tenants by deficit-round-robin; ``None`` restores
        the classic single-backlog packer."""
        with self._lock:
            self._fair_policy = policy
            if policy is not None and self._backlog:
                # migrate anything already backlogged into its tenant's lane
                for dq in self._backlog.values():
                    for seq, task in dq:
                        lane = self._lane_for(task)
                        lane.backlog.setdefault(task.slots, deque()).append(
                            (seq, task))
                        if CHAIN_TAG in task.tags or DAG_TAG in task.tags:
                            lane.has_chain_backlog = True
                self._backlog = {}
            elif policy is None and self._lanes:
                self._merge_lanes_locked()

    def _lane_for(self, task: Task) -> _Lane:
        # untagged tasks (dynamic stages minted mid-run, non-serve
        # submissions) lane by workflow namespace so they still round-robin
        # fairly rather than pooling into one anonymous lane
        tenant = str(task.tags.get("_tenant")
                     or task.tags.get("_wf_ns") or "")
        lane = self._lanes.get(tenant)
        if lane is None:
            lane = self._lanes[tenant] = _Lane()
        return lane

    def _merge_lanes_locked(self) -> None:
        """Fold every tenant lane back into the classic backlog in seq
        order (federated fallback / fair share switched off)."""
        entries = [e for lane in self._lanes.values()
                   for dq in lane.backlog.values() for e in dq]
        for lane in self._lanes.values():
            lane.backlog.clear()
            lane.has_chain_backlog = False
        for seq, task in sorted(entries):
            self._backlog.setdefault(task.slots, deque()).append((seq, task))
            if CHAIN_TAG in task.tags or DAG_TAG in task.tags:
                self._has_chain_backlog = True

    def _pick_batch_fair_locked(self, free: Optional[int],
                                fusion: bool = False) -> List[Task]:
        """Weighted deficit-round-robin over the tenant lanes.

        Each lane visit grants ``fair_quantum × weight`` members of credit,
        then runs the UNCHANGED single-tenant packer against that lane's
        private backlog (its width buckets, starvation guard and chain-hold
        state context-swapped in), charging the members actually taken.
        An atomic whole-group drain may overdraw; the debt carries and the
        lane sits out rounds until repaid — so one tenant's huge sweep
        interleaves with, rather than starves, everyone else. Because the
        per-round batch spans several lanes, same-group members from
        different tenants reach ``rts.submit`` together and pack into the
        same carriers.
        """
        if free is None:
            # capacity-blind RTS: drain every lane merged back to seq order
            merged = heapq.merge(*(dq for lane in self._lanes.values()
                                   for dq in lane.backlog.values()))
            batch = []
            for _, task in merged:
                self._backlog_uids.discard(task.uid)
                if not task.is_final:
                    batch.append(task)
            for lane in self._lanes.values():
                lane.backlog.clear()
                lane.has_chain_backlog = False
            self._chain_holding = False
            self._chain_held_ids = set()
            return batch
        tenants = list(self._lanes)
        n = len(tenants)
        merged_holding = False
        merged_held: set = set()
        batch: List[Task] = []
        remaining = free
        start = self._lane_cursor % n if n else 0
        # two sweeps: the first grants quanta, the second lets lanes later
        # in the rotation use slots earlier lanes left idle this round
        for i in range(2 * n):
            if remaining <= 0:
                break
            lane = self._lanes[tenants[(start + i) % n]]
            if not lane.backlog:
                # classic DRR: an empty lane forfeits unused credit (debt
                # from an oversized drain is kept so a resubmitting heavy
                # tenant cannot burst past its share)
                lane.deficit = min(lane.deficit, 0.0)
                continue
            if i < n:
                lane.deficit += (self.fair_quantum
                                 * self._fair_policy.weight(tenants[(start + i) % n]))
            if lane.deficit <= 0:
                continue   # still repaying an oversized group drain
            # context swap: the single-tenant packer runs on this lane
            self._backlog = lane.backlog
            self._head_skips = lane.head_skips
            self._has_chain_backlog = lane.has_chain_backlog
            self._chain_released = lane.chain_released
            picked = self._pick_batch_locked(remaining, fusion=fusion)
            lane.head_skips = self._head_skips
            lane.has_chain_backlog = self._has_chain_backlog
            lane.chain_released = self._chain_released
            merged_holding = merged_holding or self._chain_holding
            merged_held |= self._chain_held_ids
            if picked:
                batch.extend(picked)
                lane.deficit -= len(picked)
                remaining -= min(remaining, self._picked_slots)
                tel.counter("emgr_fair_grants_total",
                            tenant=tenants[(start + i) % n]).inc()
                tel.counter("emgr_fair_granted_tasks_total",
                            tenant=tenants[(start + i) % n]).inc(len(picked))
        if n:
            self._lane_cursor = (start + 1) % n
        self._backlog = {}
        self._chain_released = set()
        self._chain_holding = merged_holding
        self._chain_held_ids = merged_held
        return batch

    def _pick_batch_federated_locked(
            self, slots_map: Dict[str, "tuple[int, int]"],
            known: set,
            fusing: Optional[set] = None) -> List["tuple[str, Task]"]:
        """Placement-aware backfill over a federation's members.

        ``slots_map``: ``{member: (free, total)}`` for *active* members;
        ``known``: every member name, active or quarantined. Returns
        ``(member, task)`` placements.

        Policy: hard ``task.backend`` affinity (a task pinned to a
        quarantined member is *parked* — skipped without blocking its width
        bucket or the starvation guard; a task pinned to a member the
        federation has never heard of is forwarded anyway so the RTS can
        reject it, mirroring the wide-head hand-over); otherwise largest-fit
        backfill with least-loaded spill (most-free member that fits). The
        starvation guard is federation-wide: the oldest placeable task is
        the guard's head exactly as in the single-member packer.
        """
        self._prune_fronts_locked()
        if not self._backlog:
            return []
        free = {n: f for n, (f, _t) in slots_map.items()}
        totals = {n: t for n, (_f, t) in slots_map.items()}
        placements: List["tuple[str, Task]"] = []

        def eligible(task: Task) -> Optional[List[str]]:
            """Members the task may run on; None ⇒ parked (member exists
            but is quarantined); [] ⇒ unknown member, forward-and-reject."""
            if task.backend is None:
                return list(free)
            if task.backend in free:
                return [task.backend]
            return None if task.backend in known else []

        def try_place(task: Task,
                      pin: Optional[str] = None) -> "tuple[str, Optional[str]]":
            """Place one task; returns (status, member). ``pin`` places on
            that member without charging its free count — used to keep a
            fusible group's members together on the member that already
            charged for the group's single batched dispatch."""
            if pin is not None:
                placements.append((pin, task))
                return "placed", pin
            names = eligible(task)
            if names is None:
                return "park", None
            if not names and task.backend is not None:
                placements.append((task.backend, task))
                return "placed", task.backend  # unknown: the RTS owns the error
            fits = [n for n in names if free[n] >= task.slots]
            if not fits:
                return "full", None
            pick = max(fits, key=lambda n: free[n])
            free[pick] -= task.slots
            placements.append((pick, task))
            return "placed", pick

        # federation-wide starvation head: oldest bucket-front that is not
        # parked (a parked task cannot make progress, so it must not hold
        # the rest of the fleet hostage through the guard)
        head = None
        for dq in self._backlog.values():
            seq, task = dq[0]
            if (task.backend is not None and task.backend not in free
                    and task.backend in known):
                continue
            if head is None or seq < head[0]:
                head = (seq, task)
        if head is not None:
            htask = head[1]
            elig = eligible(htask) or []
            fits_now = (htask.backend is not None
                        and htask.backend not in known) or any(
                            free[n] >= htask.slots for n in elig)
            if not fits_now:
                cap = [totals[n] for n in elig] or [0]
                fed_idle = sum(free.values()) >= max(1, sum(totals.values()))
                if (htask.slots > max(cap) and fed_idle
                        and not self._submitted):
                    # the head can never fit any member: hand it to the
                    # largest eligible pilot, the RTS owns that error
                    self._pop_head_locked(htask)
                    self._head_skips = 0
                    target = max(elig, key=lambda n: totals[n]) if elig \
                        else htask.backend
                    return [(target, htask)]
                if self._head_skips >= self.starvation_limit:
                    return []  # hold everything: drain until the head fits
            elif self._head_skips >= self.starvation_limit:
                # starved head goes first, then backfill with what still fits
                self._pop_head_locked(htask)
                try_place(htask)
                self._head_skips = 0
        for width in sorted(self._backlog, reverse=True):
            self._take_federated_locked(width, try_place,
                                        fusing=fusing or set())
        if not placements:
            return []
        if head is None or any(t.uid == head[1].uid for _, t in placements):
            self._head_skips = 0
        else:
            self._head_skips += 1
        return placements

    def _take_federated_locked(self, width: int, try_place: Callable,
                               fusing: set) -> None:
        """Scan one width bucket: place what fits, skip over parked tasks,
        stop at the first task that is eligible but out of capacity (strict
        FIFO within a width, exactly like the single-member packer).

        Placing a ``_fusion_group``-tagged task on a member in ``fusing``
        (one whose runtime batches fused groups) pins every consecutive
        same-group task onto that member without charging its free count
        again: the group executes there as one batched dispatch (group
        keys include the backend affinity, so the pin never violates
        placement constraints). A group landing on a *scalar* member is
        never pinned — that pilot runs tasks one by one, so its members
        place and charge individually like any other work."""
        dq = self._backlog.get(width)
        if dq is None:
            return
        kept: Deque = deque()
        while dq:
            seq, task = dq.popleft()
            if task.is_final:
                self._backlog_uids.discard(task.uid)
                continue
            res, member = try_place(task)
            if res == "placed":
                self._backlog_uids.discard(task.uid)
                if member is not None and member in fusing:
                    self._drain_group_locked(
                        dq, task, lambda nxt: try_place(nxt, pin=member))
            elif res == "park":
                kept.append((seq, task))
            else:  # full
                kept.append((seq, task))
                kept.extend(dq)
                dq.clear()
        if kept:
            self._backlog[width] = kept
        else:
            del self._backlog[width]

    def _chain_valve_locked(self, submitted_any: bool,
                            quiescent: bool) -> None:
        """Anti-stall valve for the chain hold: if holds are the ONLY thing
        in the backlog and nothing is in custody for several consecutive
        QUIESCENT rounds (kick-only wakeups — while pending messages still
        stream in, a held chain is merely incomplete), the missing links
        are never coming (e.g. a downstream retry whose sibling exhausted
        its budget) — release the held chains so they run per-stage
        instead of deadlocking the workflow. By the time the valve trips,
        every earlier completion has long been routed, so per-stage
        execution resolves its inputs safely."""
        if submitted_any or not self._chain_holding:
            self._chain_stalls = 0
            return
        if not quiescent or self._submitted:
            return  # messages still flowing / work in flight: not a stall
        self._chain_stalls += 1
        if self._chain_stalls >= 3:
            self._chain_released.update(self._chain_held_ids)
            if self._fair_policy is not None:
                # fair mode: the holds live in per-lane released sets (each
                # lane prunes ids that are not its own on its next scan)
                for lane in self._lanes.values():
                    lane.chain_released.update(self._chain_held_ids)
            self._chain_stalls = 0
            self.broker.kick(PENDING_QUEUE)

    def n_backlogged(self) -> int:
        with self._lock:
            return (sum(len(dq) for dq in self._backlog.values())
                    + sum(len(dq) for lane in self._lanes.values()
                          for dq in lane.backlog.values()))

    # -- RTSCallback -------------------------------------------------------------#

    def _rts_callback(self, c: TaskCompletion) -> None:
        uid = c.uid
        to_cancel: List[str] = []
        with self._lock:
            original = self._spec_of.pop(uid, None)
            if original is not None:
                if c.exit_code != 0 and original in self._submitted:
                    # the speculative clone failed while the original is
                    # still running: drop the clone, keep the original
                    self._spec_for.pop(original, None)
                    return
                # A speculative clone finished first: report it as the
                # original and cancel the still-running original attempt.
                self._spec_for.pop(original, None)
                if c.exit_code == 0:
                    self.speculation_wins += 1
                to_cancel.append(original)  # cancel the slower original
                uid = original
            else:
                clone = self._spec_for.pop(uid, None)
                if clone is not None:
                    # the original finished first: cancel the clone
                    self._spec_of.pop(clone, None)
                    to_cancel.append(clone)
            task = self._submitted.pop(uid, None)
        if to_cancel and self.rts is not None:
            # best-effort: the winner's own uid may be in the list; RTS
            # cancel of an already-finished task is a no-op.
            try:
                self.rts.cancel([u for u in to_cancel if u != c.uid])
            except Exception:  # noqa: BLE001
                pass
        if task is None:
            return  # duplicate completion (losing speculative attempt)
        # No state advance here: this runs on the RTS's own thread, and the
        # Dequeue coalesces EXECUTED into the completion chain. The callback
        # only converts the event into a message.
        self.broker.put(DONE_QUEUE, {
            "uid": uid,
            "exit_code": c.exit_code,
            "result": c.result,
            "exception": c.exception,
            "completed_at": c.completed_at,
            "execution_seconds": c.execution_seconds,
            "staging_seconds": c.staging_seconds,
            "pilot_lost": getattr(c, "pilot_lost", False),
            "plan": getattr(c, "plan", None),
        })
        # capacity freed: wake the Emgr — but only when it actually holds
        # tasks back for slots (unconditional kicks would wake it once per
        # completion for nothing). Racing a concurrent backlog append is
        # benign: the appender's own loop runs _submit_ready afterwards.
        if self._backlog_uids:
            self.broker.kick(PENDING_QUEUE)

    # -- Heartbeat ------------------------------------------------------------#

    def _heartbeat_loop(self) -> None:
        misses = 0
        while not self._stop.is_set():
            self._stop.wait(self.heartbeat_interval)
            if self._stop.is_set():
                return
            try:
                ok = self.rts is not None and self.rts.alive()
            except Exception:  # noqa: BLE001 - a dead RTS may throw anything
                ok = False
            if ok:
                misses = 0
                if self._chain_holding and not self._submitted:
                    # drive the anti-stall valve forward: a held chain with
                    # nothing in flight generates no completion kicks, so
                    # the heartbeat supplies the wakeups the valve counts
                    self.broker.kick(PENDING_QUEUE)
                continue
            misses += 1
            if misses >= 2:
                misses = 0
                self._restart_rts()

    def _restart_rts(self) -> None:
        """Tear down the failed RTS, start a fresh one, resubmit lost tasks."""
        if self.rts_restarts >= self.max_rts_restarts:
            self.component_errors.append(
                "rts: restart budget exhausted")
            self._stop.set()
            self.broker.kick(PENDING_QUEUE)
            return
        self.rts_restarts += 1
        with self._lock:
            lost = list(self._submitted.values())
            self._spec_of.clear()
            self._spec_for.clear()
        try:
            # detach first: the dying instance must not deliver cancellation
            # completions for tasks we are about to resubmit
            self.rts.set_callback(None)
            with self.prof.measure(RTS_TEARDOWN):
                self.rts.stop()   # purge leftovers of the failed instance
        except Exception:  # noqa: BLE001
            pass
        self.acquire_resources()
        if lost:
            t0 = time.perf_counter()
            self.rts.submit(lost)
            self.prof.add(RTS_OVERHEAD, time.perf_counter() - t0)
        # fresh pilot, fresh capacity: let the Emgr re-evaluate its backlog
        self.broker.kick(PENDING_QUEUE)

    # -- Watchdog (straggler speculation) ------------------------------------#

    #: the api layer's trampoline executable (literal: the core never
    #: imports the fusion package; see fusion.engine.TRAMPOLINE)
    _TRAMPOLINE = "reg://_api.call"

    def _task_kernel(self, task: Task) -> Optional[str]:
        """The task's per-kernel telemetry label — the key every dispatch
        path observes DISPATCH_LATENCY under — or None for payloads with no
        kernel identity (``sleep://`` synthetics, unresolvable refs)."""
        if task.executable == self._TRAMPOLINE:
            key = task.kwargs.get("__fn__")
        else:
            key = task._fn if task._fn is not None else task.executable
        try:
            return self._kernel_cache[key]
        except (KeyError, TypeError):
            pass
        try:
            if task.executable == self._TRAMPOLINE:
                fn = resolve_executable(task.kwargs["__fn__"])
            else:
                fn = task.resolve()
            label = getattr(fn, "__name__", None) or str(fn)
        except Exception:  # noqa: BLE001 - no callable: no kernel label
            label = None
        try:
            self._kernel_cache[key] = label
        except TypeError:
            pass
        return label

    def _expected_duration(self, task: Task,
                           q_cache: Dict[str, Optional[float]]
                           ) -> "tuple[Optional[float], str]":
        """(expected seconds, source) for the straggler threshold: the
        kernel's measured p99 once ``speculation_min_samples`` dispatches
        exist, else the static ``duration_hint`` (cold-start fallback)."""
        kernel = self._task_kernel(task)
        if kernel is not None:
            if kernel not in q_cache:
                q = tel.quantiles(kernel)
                q_cache[kernel] = (
                    q.get("p99")
                    if (q.get("count") or 0) >= self.speculation_min_samples
                    else None)
            p99 = q_cache[kernel]
            if p99 is not None:
                return p99, "p99"
        return task.duration_hint, "hint"

    def _watchdog_loop(self) -> None:
        while not self._stop.is_set():
            self._stop.wait(self.heartbeat_interval)
            if self._stop.is_set():
                return
            rts = self.rts
            if rts is None or not hasattr(rts, "running_since"):
                continue
            try:
                running = rts.running_since()
            except Exception:  # noqa: BLE001
                continue
            # one quantile lookup per kernel per sweep, not per task
            q_cache: Dict[str, Optional[float]] = {}
            with self._lock:
                candidates = []
                for uid, elapsed in running.items():
                    task = self._submitted.get(uid)
                    if task is None or uid in self._speculated:
                        continue
                    if uid in self._spec_of:   # don't speculate on clones
                        continue
                    expect, source = self._expected_duration(task, q_cache)
                    if expect is None:
                        continue
                    threshold = max(self.straggler_min_seconds,
                                    self.straggler_factor * expect)
                    if elapsed > threshold:
                        candidates.append((task, source))
                clones = []
                for task, source in candidates:
                    clone = self._clone_for_speculation(task)
                    self._spec_of[clone.uid] = task.uid
                    self._spec_for[task.uid] = clone.uid
                    self._speculated.add(task.uid)
                    self.speculations += 1
                    if source == "p99":
                        self.speculations_from_quantile += 1
                    else:
                        self.speculations_from_hint += 1
                    tel.counter("speculation_total", source=source).inc()
                    clones.append(clone)
            if clones:
                rts.submit(clones)

    @staticmethod
    def _clone_for_speculation(task: Task) -> Task:
        # drop the federation placement hint: the clone should be free to
        # land on a different (less loaded / healthier) member than the
        # straggling original; the affinity constraint itself is kept.
        # The chain/DAG tags are dropped too: a lone clone must run as an
        # ordinary (scalar/group) task against the result store — by
        # speculation time its upstream links are long routed — instead of
        # waiting in the chain assembler for siblings that never come.
        tags = {k: v for k, v in task.tags.items()
                if k not in ("_fed_member", CHAIN_TAG, DAG_TAG)}
        clone = Task(
            name=f"{task.name}#spec",
            executable=task._fn if task._fn is not None else task.executable,
            args=task.args, kwargs=task.kwargs, slots=task.slots,
            duration_hint=task.duration_hint,
            tags={**tags, "speculative_of": task.uid},
            backend=task.backend,
        )
        return clone

    # -- introspection ------------------------------------------------------#

    def n_in_custody(self) -> int:
        with self._lock:
            return len(self._submitted)
