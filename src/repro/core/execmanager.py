"""ExecManager: the workload-management component (paper §II-B.2/3).

Subcomponents (threads):

* **Rmgr** — acquires/releases resources (starts the pilot) via the RTS.
* **Emgr** — pulls tasks from the ``pending`` queue, translates them into
  RTS submissions, tracks the submitted set.
* **RTSCallback** — receives completion events from the RTS and pushes them
  onto the ``done`` queue.
* **Heartbeat** — probes RTS liveness; on failure the AppManager tears the
  RTS down, starts a fresh instance and resubmits exactly the lost in-flight
  tasks (black-box RTS fault tolerance, §II-B.4).
* **Watchdog** (beyond paper; required at 10³+ nodes) — straggler
  mitigation via speculative re-execution: a task that exceeds
  ``straggler_factor ×`` its expected duration is cloned; the first attempt
  to finish wins, the loser is canceled and its completion deduplicated.
"""

from __future__ import annotations

import threading
import time
import traceback
from typing import Callable, Dict, List, Optional

from . import states as st
from .broker import Broker
from .profiler import ENTK_MANAGEMENT, RTS_OVERHEAD, RTS_TEARDOWN, Profiler
from .pst import Task
from .state_service import StateService
from .wfprocessor import DONE_QUEUE, PENDING_QUEUE
from ..rts.base import RTS, ResourceDescription, TaskCompletion


class ExecManager:
    def __init__(
        self,
        broker: Broker,
        svc: StateService,
        prof: Profiler,
        rts_factory: Callable[[], RTS],
        resources: ResourceDescription,
        task_index: Dict[str, Task],
        heartbeat_interval: float = 0.5,
        max_rts_restarts: int = 3,
        straggler_factor: float = 0.0,  # 0 disables speculation
        straggler_min_seconds: float = 1.0,
    ) -> None:
        self.broker = broker
        self.svc = svc
        self.prof = prof
        self.rts_factory = rts_factory
        self.resources = resources
        self.task_index = task_index
        self.heartbeat_interval = heartbeat_interval
        self.max_rts_restarts = max_rts_restarts
        self.straggler_factor = straggler_factor
        self.straggler_min_seconds = straggler_min_seconds

        self.rts: Optional[RTS] = None
        self.rts_restarts = 0
        self._submitted: Dict[str, Task] = {}   # uid -> task, in RTS custody
        self._spec_of: Dict[str, str] = {}      # clone uid -> original uid
        self._spec_for: Dict[str, str] = {}     # original uid -> clone uid
        self._speculated: set = set()           # originals already cloned
        self._lock = threading.RLock()
        self._stop = threading.Event()
        self._emgr_thread: Optional[threading.Thread] = None
        self._hb_thread: Optional[threading.Thread] = None
        self._wd_thread: Optional[threading.Thread] = None
        self.emgr_crash_hook: Optional[Callable[[], None]] = None
        self.component_errors: List[str] = []
        self.speculations = 0
        self.speculation_wins = 0

    # -- Rmgr ------------------------------------------------------------------#

    def acquire_resources(self) -> None:
        with self.prof.measure(RTS_OVERHEAD):
            self.rts = self.rts_factory()
            self.rts.set_callback(self._rts_callback)
            self.rts.start(self.resources)

    def release_resources(self) -> None:
        if self.rts is not None:
            with self.prof.measure(RTS_TEARDOWN):
                self.rts.stop()

    def resize(self, slots: int) -> None:
        """Elastic scaling passthrough."""
        if self.rts is not None:
            self.rts.resize(slots)
            self.resources.slots = slots

    # -- lifecycle ----------------------------------------------------------#

    def start(self) -> None:
        self._stop.clear()
        self.start_emgr()
        self._hb_thread = threading.Thread(target=self._heartbeat_loop,
                                           daemon=True, name="em-heartbeat")
        self._hb_thread.start()
        if self.straggler_factor > 0:
            self._wd_thread = threading.Thread(target=self._watchdog_loop,
                                               daemon=True, name="em-watchdog")
            self._wd_thread.start()

    def start_emgr(self) -> None:
        self._emgr_thread = threading.Thread(
            target=self._guarded, args=(self._emgr_loop, "emgr"),
            daemon=True, name="em-emgr")
        self._emgr_thread.start()

    def stop(self) -> None:
        self._stop.set()
        for t in (self._emgr_thread, self._hb_thread, self._wd_thread):
            if t is not None:
                t.join(timeout=5.0)
        self._emgr_thread = self._hb_thread = self._wd_thread = None
        self.release_resources()

    def threads_alive(self) -> Dict[str, bool]:
        return {"emgr": bool(self._emgr_thread
                             and self._emgr_thread.is_alive())}

    def _guarded(self, fn: Callable[[], None], name: str) -> None:
        try:
            fn()
        except Exception:  # noqa: BLE001
            self.component_errors.append(
                f"{name}: {traceback.format_exc(limit=5)}")

    # -- Emgr ------------------------------------------------------------------#

    def _emgr_loop(self) -> None:
        while not self._stop.is_set():
            if self.emgr_crash_hook is not None:
                self.emgr_crash_hook()
            msgs = self.broker.get_many(PENDING_QUEUE, 128, timeout=0.05)
            if not msgs:
                continue
            t0 = time.perf_counter()
            batch: List[Task] = []
            for tag, uid in msgs:
                task = self.task_index.get(uid)
                self.broker.ack(PENDING_QUEUE, tag)
                if task is None:
                    continue
                self.svc.advance(task, st.SUBMITTING, transact=False)
                with self._lock:
                    self._submitted[task.uid] = task
                batch.append(task)
            self.prof.add(ENTK_MANAGEMENT, time.perf_counter() - t0)
            if batch:
                t1 = time.perf_counter()
                self.rts.submit(batch)
                for task in batch:
                    task.submitted_at = time.time()
                    self.svc.advance(task, st.SUBMITTED, transact=False)
                self.prof.add(RTS_OVERHEAD, time.perf_counter() - t1)

    # -- RTSCallback -------------------------------------------------------------#

    def _rts_callback(self, c: TaskCompletion) -> None:
        uid = c.uid
        to_cancel: List[str] = []
        with self._lock:
            original = self._spec_of.pop(uid, None)
            if original is not None:
                if c.exit_code != 0 and original in self._submitted:
                    # the speculative clone failed while the original is
                    # still running: drop the clone, keep the original
                    self._spec_for.pop(original, None)
                    return
                # A speculative clone finished first: report it as the
                # original and cancel the still-running original attempt.
                self._spec_for.pop(original, None)
                if c.exit_code == 0:
                    self.speculation_wins += 1
                to_cancel.append(original)  # cancel the slower original
                uid = original
            else:
                clone = self._spec_for.pop(uid, None)
                if clone is not None:
                    # the original finished first: cancel the clone
                    self._spec_of.pop(clone, None)
                    to_cancel.append(clone)
            task = self._submitted.pop(uid, None)
        if to_cancel and self.rts is not None:
            # best-effort: the winner's own uid may be in the list; RTS
            # cancel of an already-finished task is a no-op.
            try:
                self.rts.cancel([u for u in to_cancel if u != c.uid])
            except Exception:  # noqa: BLE001
                pass
        if task is None:
            return  # duplicate completion (losing speculative attempt)
        task_state = self.task_index.get(uid)
        if task_state is not None and task_state.state == st.SUBMITTED:
            self.svc.advance(task_state, st.EXECUTED, transact=False)
        self.broker.put(DONE_QUEUE, {
            "uid": uid,
            "exit_code": c.exit_code,
            "result": c.result,
            "exception": c.exception,
            "completed_at": c.completed_at,
            "execution_seconds": c.execution_seconds,
            "staging_seconds": c.staging_seconds,
        })

    # -- Heartbeat ------------------------------------------------------------#

    def _heartbeat_loop(self) -> None:
        misses = 0
        while not self._stop.is_set():
            time.sleep(self.heartbeat_interval)
            if self._stop.is_set():
                return
            try:
                ok = self.rts is not None and self.rts.alive()
            except Exception:  # noqa: BLE001 - a dead RTS may throw anything
                ok = False
            if ok:
                misses = 0
                continue
            misses += 1
            if misses >= 2:
                misses = 0
                self._restart_rts()

    def _restart_rts(self) -> None:
        """Tear down the failed RTS, start a fresh one, resubmit lost tasks."""
        if self.rts_restarts >= self.max_rts_restarts:
            self.component_errors.append(
                "rts: restart budget exhausted")
            self._stop.set()
            return
        self.rts_restarts += 1
        with self._lock:
            lost = list(self._submitted.values())
            self._spec_of.clear()
            self._spec_for.clear()
        try:
            # detach first: the dying instance must not deliver cancellation
            # completions for tasks we are about to resubmit
            self.rts.set_callback(None)
            with self.prof.measure(RTS_TEARDOWN):
                self.rts.stop()   # purge leftovers of the failed instance
        except Exception:  # noqa: BLE001
            pass
        self.acquire_resources()
        if lost:
            t0 = time.perf_counter()
            self.rts.submit(lost)
            self.prof.add(RTS_OVERHEAD, time.perf_counter() - t0)

    # -- Watchdog (straggler speculation) ------------------------------------#

    def _watchdog_loop(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.heartbeat_interval)
            rts = self.rts
            if rts is None or not hasattr(rts, "running_since"):
                continue
            try:
                running = rts.running_since()
            except Exception:  # noqa: BLE001
                continue
            with self._lock:
                candidates = []
                for uid, elapsed in running.items():
                    task = self._submitted.get(uid)
                    if task is None or uid in self._speculated:
                        continue
                    if uid in self._spec_of:   # don't speculate on clones
                        continue
                    expect = task.duration_hint
                    if expect is None:
                        continue
                    threshold = max(self.straggler_min_seconds,
                                    self.straggler_factor * expect)
                    if elapsed > threshold:
                        candidates.append(task)
                clones = []
                for task in candidates:
                    clone = self._clone_for_speculation(task)
                    self._spec_of[clone.uid] = task.uid
                    self._spec_for[task.uid] = clone.uid
                    self._speculated.add(task.uid)
                    self.speculations += 1
                    clones.append(clone)
            if clones:
                rts.submit(clones)

    @staticmethod
    def _clone_for_speculation(task: Task) -> Task:
        clone = Task(
            name=f"{task.name}#spec",
            executable=task._fn if task._fn is not None else task.executable,
            args=task.args, kwargs=task.kwargs, slots=task.slots,
            duration_hint=task.duration_hint,
            tags={**task.tags, "speculative_of": task.uid},
        )
        return clone

    # -- introspection ------------------------------------------------------#

    def n_in_custody(self) -> int:
        with self._lock:
            return len(self._submitted)
