"""State machines for Tasks, Stages and Pipelines.

The paper (§II-B.3) specifies that tasks, stages and pipelines undergo multiple
state transitions in both WFProcessor and ExecManager, synchronized with the
AppManager through dedicated queues. This module defines those states and the
legal transition tables; every transition anywhere in the toolkit goes through
:func:`validate_transition`, and the AppManager journals each one as a
transaction so that a restarted toolkit can resume from the last transition.

State values are ordered integers so "progress" comparisons are cheap; FINAL
states compare equal in precedence.
"""

from __future__ import annotations

from typing import Dict, Tuple

from .exceptions import StateTransitionError

# --------------------------------------------------------------------------- #
# Task states
# --------------------------------------------------------------------------- #

INITIAL = "DESCRIBED"

# Workflow-management side (WFProcessor)
SCHEDULING = "SCHEDULING"          # tagged for execution, local copy made
SCHEDULED = "SCHEDULED"            # pushed to the Pending queue

# Workload-management side (ExecManager)
SUBMITTING = "SUBMITTING"          # pulled from Pending, translating to RTS task
SUBMITTED = "SUBMITTED"            # handed to the RTS (black box beyond this)
EXECUTED = "EXECUTED"              # RTS callback reported completion (any code)

# Final states (Dequeue tags on the return code)
DONE = "DONE"
FAILED = "FAILED"
CANCELED = "CANCELED"

TASK_FINAL = (DONE, FAILED, CANCELED)

TASK_STATES: Tuple[str, ...] = (
    INITIAL,
    SCHEDULING,
    SCHEDULED,
    SUBMITTING,
    SUBMITTED,
    EXECUTED,
    DONE,
    FAILED,
    CANCELED,
)

# numeric precedence for ordering / progress bars
_TASK_ORDER: Dict[str, int] = {
    INITIAL: 0,
    SCHEDULING: 1,
    SCHEDULED: 2,
    SUBMITTING: 3,
    SUBMITTED: 4,
    EXECUTED: 5,
    DONE: 6,
    FAILED: 6,
    CANCELED: 6,
}

# Legal transitions.  FAILED -> SCHEDULING is the resubmission path: a failed
# task re-enters the workflow layer without touching DESCRIBED, so completed
# work elsewhere is never repeated (paper requirement: multiple attempts
# without restarting completed tasks).
_TASK_TRANSITIONS: Dict[str, Tuple[str, ...]] = {
    INITIAL: (SCHEDULING, CANCELED),
    SCHEDULING: (SCHEDULED, CANCELED),
    SCHEDULED: (SUBMITTING, CANCELED),
    SUBMITTING: (SUBMITTED, FAILED, CANCELED),
    SUBMITTED: (EXECUTED, FAILED, CANCELED),
    EXECUTED: (DONE, FAILED, CANCELED),
    DONE: (),
    FAILED: (SCHEDULING,),  # resubmission
    CANCELED: (),
}

# --------------------------------------------------------------------------- #
# Stage states
# --------------------------------------------------------------------------- #

STAGE_INITIAL = "DESCRIBED"
STAGE_SCHEDULING = "SCHEDULING"
STAGE_SCHEDULED = "SCHEDULED"
STAGE_DONE = "DONE"
STAGE_FAILED = "FAILED"
STAGE_CANCELED = "CANCELED"

STAGE_FINAL = (STAGE_DONE, STAGE_FAILED, STAGE_CANCELED)

STAGE_STATES: Tuple[str, ...] = (
    STAGE_INITIAL,
    STAGE_SCHEDULING,
    STAGE_SCHEDULED,
    STAGE_DONE,
    STAGE_FAILED,
    STAGE_CANCELED,
)

_STAGE_TRANSITIONS: Dict[str, Tuple[str, ...]] = {
    STAGE_INITIAL: (STAGE_SCHEDULING, STAGE_CANCELED),
    STAGE_SCHEDULING: (STAGE_SCHEDULED, STAGE_CANCELED),
    STAGE_SCHEDULED: (STAGE_DONE, STAGE_FAILED, STAGE_CANCELED),
    STAGE_DONE: (),
    STAGE_FAILED: (STAGE_SCHEDULING,),  # pipeline-level retry
    STAGE_CANCELED: (),
}

# --------------------------------------------------------------------------- #
# Pipeline states
# --------------------------------------------------------------------------- #

PIPELINE_INITIAL = "DESCRIBED"
PIPELINE_SCHEDULING = "SCHEDULING"
PIPELINE_DONE = "DONE"
PIPELINE_FAILED = "FAILED"
PIPELINE_CANCELED = "CANCELED"

PIPELINE_FINAL = (PIPELINE_DONE, PIPELINE_FAILED, PIPELINE_CANCELED)

PIPELINE_STATES: Tuple[str, ...] = (
    PIPELINE_INITIAL,
    PIPELINE_SCHEDULING,
    PIPELINE_DONE,
    PIPELINE_FAILED,
    PIPELINE_CANCELED,
)

_PIPELINE_TRANSITIONS: Dict[str, Tuple[str, ...]] = {
    PIPELINE_INITIAL: (PIPELINE_SCHEDULING, PIPELINE_CANCELED),
    PIPELINE_SCHEDULING: (PIPELINE_DONE, PIPELINE_FAILED, PIPELINE_CANCELED),
    PIPELINE_DONE: (),
    PIPELINE_FAILED: (PIPELINE_SCHEDULING,),
    PIPELINE_CANCELED: (),
}

_TABLES = {
    "task": _TASK_TRANSITIONS,
    "stage": _STAGE_TRANSITIONS,
    "pipeline": _PIPELINE_TRANSITIONS,
}


def validate_transition(kind: str, uid: str, from_state: str, to_state: str) -> None:
    """Raise :class:`StateTransitionError` unless ``from_state -> to_state`` is legal.

    ``kind`` is one of ``task|stage|pipeline``.
    """
    table = _TABLES[kind]
    if from_state not in table:
        raise StateTransitionError(f"{kind} {uid}", from_state, to_state)
    if to_state not in table[from_state]:
        raise StateTransitionError(f"{kind} {uid}", from_state, to_state)


def legal_next(kind: str, from_state: str) -> Tuple[str, ...]:
    """Return the set of legal successor states (used by property tests)."""
    return _TABLES[kind].get(from_state, ())


def task_order(state: str) -> int:
    return _TASK_ORDER[state]
