"""StateService: every PST state transition in the toolkit goes through here.

Design (paper §II-B.3): components synchronize all transitions with the
AppManager by pushing messages through dedicated queues; the AppManager
acknowledges updates, which makes it the only stateful component and makes
updates transactional.

In-process realization: ``advance()`` (1) validates the transition against
the state tables, (2) applies it to the master object, (3) publishes a
transition message on the ``states`` queue for the Synchronizer to journal
and account, and (4) — when ``transact=True`` — blocks until the
Synchronizer acknowledges that the transition reached the write-ahead
journal. Final states default to transactional; high-frequency intermediate
states default to asynchronous journaling (ordering is still preserved by
the single-consumer Synchronizer). ``strict`` mode forces every transition
to be transactional, reproducing the paper's fully-synchronous behaviour
(and its management overhead — measured in the Fig. 7 benchmarks).

``durable=False`` (no write-ahead journal configured) downgrades the
*default* final-state transactionality to asynchronous publishing: with no
WAL behind the Synchronizer there is nothing for the ack to make durable,
and the round-trip would only couple the Dequeue hot path to the
Synchronizer's queue depth. Explicit ``strict`` mode still blocks.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Union

from .. import telemetry as tel
from . import states as st
from .broker import Broker
from .pst import Pipeline, Stage, Task

STATES_QUEUE = "states"

_FINAL = set(st.TASK_FINAL) | set(st.STAGE_FINAL) | set(st.PIPELINE_FINAL)

PSTObject = Union[Task, Stage, Pipeline]


def _kind(obj: PSTObject) -> str:
    if isinstance(obj, Task):
        return "task"
    if isinstance(obj, Stage):
        return "stage"
    return "pipeline"


class StateService:
    def __init__(self, broker: Broker, strict: bool = False,
                 ack_timeout: float = 10.0, durable: bool = True) -> None:
        self.broker = broker
        self.strict = strict
        self.ack_timeout = ack_timeout
        self.durable = durable
        broker.declare(STATES_QUEUE)
        self._lock = threading.Lock()

    def advance(self, obj: PSTObject, to_state: str,
                transact: Optional[bool] = None,
                sink: Optional[list] = None,
                **extra: Any) -> None:
        self.advance_seq(obj, (to_state,), transact=transact, sink=sink,
                         **extra)

    def flush(self, sink: list) -> None:
        """Publish messages deferred into ``sink`` in one queue operation."""
        if sink:
            self.broker.put_many(STATES_QUEUE, sink)
            sink.clear()

    def advance_seq(self, obj: PSTObject, to_states: Any,
                    transact: Optional[bool] = None,
                    sink: Optional[list] = None,
                    **extra: Any) -> None:
        """Apply a chain of transitions atomically and publish ONE message.

        Micro-transitions that always travel together (SCHEDULING→SCHEDULED,
        SUBMITTING→SUBMITTED, EXECUTED→DONE, …) each used to cost a lock
        round and a queue notify; on the O(10⁴)-task hot path those
        synchronization points dominate management overhead, so call sites
        coalesce them. Every hop is still validated in order and the
        journal records the full ``via`` chain.

        ``sink``: defer the publish into the caller's buffer instead of
        putting immediately. The caller must :meth:`flush` the sink before
        any hand-off that lets another component advance the same object
        (pending-queue puts, RTS submission, releasing the pipeline lock),
        so the states queue still sees every object's transitions in order
        while a batch of events costs one queue operation, not one per
        transition. Transactional messages flush the sink first and are
        never deferred.
        """
        if not to_states:
            return
        kind = _kind(obj)
        # No service-global lock here: a global lock would couple every
        # component's hot path to every other's (measured: it and the old
        # WFProcessor-global lock dominated management overhead at O(10⁴)
        # pipelines). Per-object ordering is owned by the pipeline lock
        # (WFProcessor scheduling/closure and AppManager.cancel both take
        # it); the ExecManager's submission chain runs outside that lock
        # and therefore guards its advance with a try/except, dropping
        # tasks that were finalized (canceled) concurrently.
        frm = obj.state
        for s in to_states:
            obj.advance(s)  # validates; raises StateTransitionError
        to_state = to_states[-1]
        if tel.enabled():
            # gated: this is THE hottest chokepoint in the toolkit — one
            # call per PST transition batch at O(10⁴) tasks. Off by
            # default; when tracing is on, the counter makes the state
            # machine's traffic visible per kind and destination state.
            tel.counter("state_transitions_total", kind=kind,
                        to=to_state).inc()
        if transact is None:
            transact = self.strict or (self.durable and to_state in _FINAL)
        if (not transact and not self.durable and not self.strict
                and to_state not in _FINAL):
            # Without a WAL nothing consumes intermediate states — the live
            # state table is only ever read for final states and the objects
            # themselves carry their current state. Skipping the publish
            # keeps the O(10⁴)-task hot path off the states queue entirely
            # between an entity's scheduling and its completion.
            return
        msg: Dict[str, Any] = {
            "type": "transition", "kind": kind, "uid": obj.uid,
            "name": obj.name, "frm": frm, "to": to_state,
        }
        ns = getattr(obj, "ns", None)
        if ns is not None:
            msg["ns"] = ns
        if len(to_states) > 1:
            msg["via"] = list(to_states[:-1])
        if extra:
            msg["extra"] = extra
        if not transact and sink is not None:
            sink.append(msg)
            return
        if sink is not None:
            self.flush(sink)  # earlier deferred states must land first
        ack: Optional[threading.Event] = None
        if transact:
            ack = threading.Event()
            msg["_ack"] = ack
        self.broker.put(STATES_QUEUE, msg)
        if ack is not None:
            ack.wait(self.ack_timeout)
