"""StateService: every PST state transition in the toolkit goes through here.

Design (paper §II-B.3): components synchronize all transitions with the
AppManager by pushing messages through dedicated queues; the AppManager
acknowledges updates, which makes it the only stateful component and makes
updates transactional.

In-process realization: ``advance()`` (1) validates the transition against
the state tables, (2) applies it to the master object, (3) publishes a
transition message on the ``states`` queue for the Synchronizer to journal
and account, and (4) — when ``transact=True`` — blocks until the
Synchronizer acknowledges that the transition reached the write-ahead
journal. Final states default to transactional; high-frequency intermediate
states default to asynchronous journaling (ordering is still preserved by
the single-consumer Synchronizer). ``strict`` mode forces every transition
to be transactional, reproducing the paper's fully-synchronous behaviour
(and its management overhead — measured in the Fig. 7 benchmarks).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional, Union

from . import states as st
from .broker import Broker
from .pst import Pipeline, Stage, Task

STATES_QUEUE = "states"

_FINAL = set(st.TASK_FINAL) | set(st.STAGE_FINAL) | set(st.PIPELINE_FINAL)

PSTObject = Union[Task, Stage, Pipeline]


def _kind(obj: PSTObject) -> str:
    if isinstance(obj, Task):
        return "task"
    if isinstance(obj, Stage):
        return "stage"
    return "pipeline"


class StateService:
    def __init__(self, broker: Broker, strict: bool = False,
                 ack_timeout: float = 10.0) -> None:
        self.broker = broker
        self.strict = strict
        self.ack_timeout = ack_timeout
        broker.declare(STATES_QUEUE)
        self._lock = threading.Lock()

    def advance(self, obj: PSTObject, to_state: str,
                transact: Optional[bool] = None,
                **extra: Any) -> None:
        kind = _kind(obj)
        with self._lock:
            frm = obj.state
            obj.advance(to_state)  # validates; raises StateTransitionError
        if transact is None:
            transact = self.strict or to_state in _FINAL
        msg: Dict[str, Any] = {
            "type": "transition", "kind": kind, "uid": obj.uid,
            "name": obj.name, "frm": frm, "to": to_state,
        }
        if extra:
            msg["extra"] = extra
        ack: Optional[threading.Event] = None
        if transact:
            ack = threading.Event()
            msg["_ack"] = ack
        self.broker.put(STATES_QUEUE, msg)
        if ack is not None:
            ack.wait(self.ack_timeout)
