"""The PST application model: Pipelines of Stages of Tasks (paper §II-B.1).

* **Task** — stand-alone computation with well-defined inputs, outputs,
  termination criteria and dedicated resources.
* **Stage** — a set of tasks with no mutual dependences (concurrent).
* **Pipeline** — a list of stages; stage *i* runs only after stage *i-1*.

All pipelines of an application run concurrently.  Branching/adaptivity does
not alter the PST semantics: a stage may carry a ``post_exec`` callback that,
once the stage reaches a final state, may append new stages to its pipeline
(the paper's "branching events specified as tasks where a decision is made").

Objects are plain Python with dict (de)serialization because EnTK copies
entities between components via queues and journals every transition; the
callable payload of a task is carried by reference through a process-local
registry so that descriptions remain serializable.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from . import states, uid
from .exceptions import MissingError, TypeError_, ValueError_

# --------------------------------------------------------------------------- #
# Executable registry
# --------------------------------------------------------------------------- #
# Tasks journaled to disk must be re-creatable on resume, so callables are
# registered under a name ("reg://<name>").  Unregistered raw callables are
# allowed for convenience but marked non-resumable.

_EXECUTABLE_REGISTRY: Dict[str, Callable[..., Any]] = {}
_registry_lock = threading.Lock()


def register_executable(name: str, fn: Callable[..., Any]) -> str:
    """Register ``fn`` under ``name``; returns the ``reg://`` uri for Task.executable."""
    with _registry_lock:
        _EXECUTABLE_REGISTRY[name] = fn
    return f"reg://{name}"


def registered_executable(name: str) -> Optional[Callable[..., Any]]:
    """The callable registered under ``name``, or None (no ``reg://`` prefix).

    Used by the declarative API to auto-register task functions without
    silently re-binding a name that already belongs to a different callable.
    """
    with _registry_lock:
        return _EXECUTABLE_REGISTRY.get(name)


def resolve_executable(ref: str) -> Callable[..., Any]:
    name = ref[len("reg://"):]
    with _registry_lock:
        try:
            return _EXECUTABLE_REGISTRY[name]
        except KeyError:
            raise MissingError(f"no executable registered under {name!r}") from None


# --------------------------------------------------------------------------- #
# Task
# --------------------------------------------------------------------------- #

class Task:
    """A computational task.

    ``executable`` is one of:

    * ``"sleep://<seconds>"`` — a synthetic task of fixed duration (the paper's
      ``sleep`` workload; honoured by Local and Simulated RTSes),
    * ``"reg://<name>"`` — a registered Python callable (journal-resumable),
    * a raw Python callable (convenient, not resumable across restarts).

    ``slots`` expresses the resource requirement in device-slots (the paper's
    cores-per-task, our TPU-devices-per-task). ``max_retries`` is the
    resubmission budget of the paper's failure model.

    ``backend`` is an optional placement affinity for federated execution: the
    name of the :class:`~repro.rts.federation.FederatedRTS` member the task
    must run on (e.g. a device pool vs a CPU pool in one mixed fleet). Unset
    means the task may run on any member (least-loaded spill).
    """

    __slots__ = (
        "uid", "name", "executable", "args", "kwargs", "slots",
        "duration_hint", "max_retries", "retries", "state", "state_history",
        "exit_code", "result", "exception", "upload_input_data",
        "copy_input_data", "copy_output_data", "tags", "backend",
        "parent_stage", "parent_pipeline", "submitted_at", "completed_at",
        "ns", "_fn",
    )

    def __init__(
        self,
        name: str = "",
        executable: Any = None,
        args: Sequence[Any] = (),
        kwargs: Optional[Dict[str, Any]] = None,
        slots: int = 1,
        duration_hint: Optional[float] = None,
        max_retries: int = 0,
        upload_input_data: Optional[List[str]] = None,
        copy_input_data: Optional[List[str]] = None,
        copy_output_data: Optional[List[str]] = None,
        tags: Optional[Dict[str, Any]] = None,
        backend: Optional[str] = None,
    ) -> None:
        if not isinstance(slots, int) or slots < 1:
            raise ValueError_(f"task slots must be a positive int, got {slots!r}")
        self.uid = uid.generate("task")
        self.name = name or self.uid
        self._fn: Optional[Callable[..., Any]] = None
        if callable(executable):
            self._fn = executable
            executable = f"callable://{getattr(executable, '__name__', 'anonymous')}"
        if executable is None:
            raise MissingError("task requires an executable")
        if not isinstance(executable, str):
            raise TypeError_(f"executable must be str|callable, got {type(executable)}")
        self.executable: str = executable
        self.args = list(args)
        self.kwargs = dict(kwargs or {})
        self.slots = slots
        self.duration_hint = duration_hint
        self.max_retries = max_retries
        self.retries = 0
        self.state = states.INITIAL
        self.state_history: List[Dict[str, Any]] = [
            {"state": states.INITIAL, "t": time.time()}
        ]
        self.exit_code: Optional[int] = None
        self.result: Any = None
        self.exception: Optional[str] = None
        self.upload_input_data = list(upload_input_data or [])
        self.copy_input_data = list(copy_input_data or [])
        self.copy_output_data = list(copy_output_data or [])
        self.tags = dict(tags or {})
        self.backend = backend
        self.parent_stage: Optional[str] = None
        self.parent_pipeline: Optional[str] = None
        self.submitted_at: Optional[float] = None
        self.completed_at: Optional[float] = None
        # Workflow namespace (api.compile mints one per workflow). Stamped by
        # the compiler; journal transitions carry it so a multi-tenant service
        # can route each record to the owning tenant's journal.
        self.ns: Optional[str] = None

    # -- state ------------------------------------------------------------- #

    def advance(self, to_state: str) -> None:
        states.validate_transition("task", self.uid, self.state, to_state)
        self.state = to_state
        self.state_history.append({"state": to_state, "t": time.time()})

    @property
    def is_final(self) -> bool:
        return self.state in states.TASK_FINAL

    @property
    def resumable(self) -> bool:
        return not self.executable.startswith("callable://")

    def resolve(self) -> Callable[..., Any]:
        """Return the callable this task runs (RTS-side)."""
        if self._fn is not None:
            return self._fn
        if self.executable.startswith("reg://"):
            return resolve_executable(self.executable)
        raise MissingError(f"task {self.uid} has no resolvable executable "
                           f"({self.executable!r})")

    # -- (de)serialization --------------------------------------------------#

    def to_dict(self) -> Dict[str, Any]:
        return {
            "uid": self.uid,
            "name": self.name,
            "executable": self.executable,
            "args": self.args,
            "kwargs": self.kwargs,
            "slots": self.slots,
            "duration_hint": self.duration_hint,
            "max_retries": self.max_retries,
            "retries": self.retries,
            "state": self.state,
            "exit_code": self.exit_code,
            "result": self.result,
            "exception": self.exception,
            "upload_input_data": self.upload_input_data,
            "copy_input_data": self.copy_input_data,
            "copy_output_data": self.copy_output_data,
            "tags": self.tags,
            "backend": self.backend,
            "parent_stage": self.parent_stage,
            "parent_pipeline": self.parent_pipeline,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Task":
        t = cls.__new__(cls)
        t._fn = None
        t.uid = d["uid"]
        t.name = d["name"]
        t.executable = d["executable"]
        t.args = list(d.get("args", ()))
        t.kwargs = dict(d.get("kwargs", {}))
        t.slots = d.get("slots", 1)
        t.duration_hint = d.get("duration_hint")
        t.max_retries = d.get("max_retries", 0)
        t.retries = d.get("retries", 0)
        t.state = d.get("state", states.INITIAL)
        t.state_history = [{"state": t.state, "t": time.time()}]
        t.exit_code = d.get("exit_code")
        t.result = d.get("result")
        t.exception = d.get("exception")
        t.upload_input_data = list(d.get("upload_input_data", ()))
        t.copy_input_data = list(d.get("copy_input_data", ()))
        t.copy_output_data = list(d.get("copy_output_data", ()))
        t.tags = dict(d.get("tags", {}))
        t.backend = d.get("backend")
        t.parent_stage = d.get("parent_stage")
        t.parent_pipeline = d.get("parent_pipeline")
        t.submitted_at = None
        t.completed_at = None
        t.ns = d.get("ns") or d.get("tags", {}).get("_wf_ns")
        return t

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<Task {self.uid} [{self.state}] {self.executable}>"


# --------------------------------------------------------------------------- #
# Stage
# --------------------------------------------------------------------------- #

class Stage:
    """A set of mutually independent tasks, executed concurrently.

    Stage closure is O(1) per task completion: when the stage is scheduled
    the WFProcessor arms ``begin_execution`` with the number of tasks still
    expected to reach a final state, and every final completion decrements
    that counter via ``note_task_final``. The counters are only ever touched
    under the WFProcessor's lock, so they are plain ints.
    """

    __slots__ = ("uid", "name", "tasks", "state", "state_history",
                 "post_exec", "parent_pipeline", "ns", "_pending", "_nfailed")

    def __init__(self, name: str = "",
                 post_exec: Optional[Callable[["Stage", "Pipeline"], None]] = None
                 ) -> None:
        self.uid = uid.generate("stage")
        self.name = name or self.uid
        self.tasks: List[Task] = []
        self.state = states.STAGE_INITIAL
        self.state_history: List[Dict[str, Any]] = [
            {"state": self.state, "t": time.time()}
        ]
        # Adaptivity hook: called by the WFProcessor when the stage reaches a
        # final state, with (stage, pipeline); may append stages to the
        # pipeline (the paper's branching-as-decision-task).
        self.post_exec = post_exec
        self.parent_pipeline: Optional[str] = None
        self.ns: Optional[str] = None   # workflow namespace (see Task.ns)
        self._pending = -1      # armed by begin_execution; -1 = not scheduled
        self._nfailed = 0

    def add_tasks(self, tasks: Any) -> None:
        if isinstance(tasks, Task):
            tasks = [tasks]
        for t in tasks:
            if not isinstance(t, Task):
                raise TypeError_(f"Stage.add_tasks expects Task, got {type(t)}")
            t.parent_stage = self.uid
            # tasks may be added after the stage already joined a pipeline
            if self.parent_pipeline is not None:
                t.parent_pipeline = self.parent_pipeline
            self.tasks.append(t)

    def advance(self, to_state: str) -> None:
        states.validate_transition("stage", self.uid, self.state, to_state)
        self.state = to_state
        self.state_history.append({"state": to_state, "t": time.time()})

    @property
    def is_final(self) -> bool:
        return self.state in states.STAGE_FINAL

    # -- O(1) closure accounting -------------------------------------------- #

    def begin_execution(self, pending: int) -> None:
        """Arm the completion countdown: ``pending`` tasks still owe a final
        state (retries keep a task pending; resumed tasks never count)."""
        self._pending = pending

    def note_task_final(self, failed: bool) -> None:
        """Record one task reaching a *terminal* final state (no retry left)."""
        if self._pending > 0:
            self._pending -= 1
        if failed:
            self._nfailed += 1

    @property
    def pending_tasks(self) -> int:
        """Tasks still expected to complete; -1 until the stage is scheduled."""
        return self._pending

    @property
    def failed_tasks(self) -> int:
        return self._nfailed

    def to_dict(self) -> Dict[str, Any]:
        return {
            "uid": self.uid,
            "name": self.name,
            "state": self.state,
            "parent_pipeline": self.parent_pipeline,
            "tasks": [t.to_dict() for t in self.tasks],
        }

    def __repr__(self) -> str:  # pragma: no cover
        return f"<Stage {self.uid} [{self.state}] ntasks={len(self.tasks)}>"


# --------------------------------------------------------------------------- #
# Pipeline
# --------------------------------------------------------------------------- #

class Pipeline:
    """An ordered list of stages. Stage *i* starts only after *i-1* is final."""

    __slots__ = ("uid", "name", "stages", "state", "state_history",
                 "_cursor", "lock", "ns", "_nfailed", "_append_listener")

    def __init__(self, name: str = "") -> None:
        self.uid = uid.generate("pipeline")
        self.name = name or self.uid
        self.stages: List[Stage] = []
        self.state = states.PIPELINE_INITIAL
        self.state_history: List[Dict[str, Any]] = [
            {"state": self.state, "t": time.time()}
        ]
        self._cursor = 0          # index of the next stage to schedule
        self.ns: Optional[str] = None   # workflow namespace (see Task.ns)
        # Adaptive post_exec callbacks append stages concurrently with the
        # WFProcessor reading them; both sides take this lock.
        self.lock = threading.RLock()
        self._nfailed = 0         # terminally-failed tasks, pipeline-wide
        # Dirty-notification hook: the WFProcessor registers a callback so
        # stages appended at runtime (post_exec adaptivity, or any other
        # thread) mark this pipeline dirty instead of relying on a poll.
        self._append_listener: Optional[Callable[[str], None]] = None

    def set_append_listener(self,
                            cb: Optional[Callable[[str], None]]) -> None:
        """Register ``cb(pipeline_uid)`` to fire whenever stages are added."""
        self._append_listener = cb

    def add_stages(self, stage_or_stages: Any) -> None:
        if isinstance(stage_or_stages, Stage):
            stage_or_stages = [stage_or_stages]
        with self.lock:
            for s in stage_or_stages:
                if not isinstance(s, Stage):
                    raise TypeError_(
                        f"Pipeline.add_stages expects Stage, got {type(s)}")
                s.parent_pipeline = self.uid
                for t in s.tasks:
                    t.parent_pipeline = self.uid
                self.stages.append(s)
            listener = self._append_listener
        if listener is not None:
            listener(self.uid)

    def advance(self, to_state: str) -> None:
        states.validate_transition("pipeline", self.uid, self.state, to_state)
        self.state = to_state
        self.state_history.append({"state": to_state, "t": time.time()})

    # -- scheduling cursor --------------------------------------------------#

    def next_stage(self) -> Optional[Stage]:
        """Return the next schedulable stage, or None if exhausted/blocked."""
        with self.lock:
            if self._cursor >= len(self.stages):
                return None
            stage = self.stages[self._cursor]
            if stage.state == states.STAGE_INITIAL:
                return stage
            if stage.is_final:
                # cursor catch-up (stage finished; point at the following one)
                self._cursor += 1
                return self.next_stage()
            return None  # current stage still executing

    def mark_stage_final(self, stage_uid: str) -> None:
        with self.lock:
            if (self._cursor < len(self.stages)
                    and self.stages[self._cursor].uid == stage_uid):
                self._cursor += 1

    @property
    def completed(self) -> bool:
        with self.lock:
            return self._cursor >= len(self.stages)

    @property
    def is_final(self) -> bool:
        return self.state in states.PIPELINE_FINAL

    # -- O(1) closure accounting -------------------------------------------- #

    def note_task_failed(self) -> None:
        """Record one terminally-failed task (WFProcessor-lock protected)."""
        self._nfailed += 1

    @property
    def failed_tasks(self) -> int:
        return self._nfailed

    @property
    def ntasks(self) -> int:
        with self.lock:
            return sum(len(s.tasks) for s in self.stages)

    def to_dict(self) -> Dict[str, Any]:
        with self.lock:
            return {
                "uid": self.uid,
                "name": self.name,
                "state": self.state,
                "cursor": self._cursor,
                "stages": [s.to_dict() for s in self.stages],
            }

    def __repr__(self) -> str:  # pragma: no cover
        return (f"<Pipeline {self.uid} [{self.state}] "
                f"nstages={len(self.stages)} cursor={self._cursor}>")


# --------------------------------------------------------------------------- #
# WorkflowIndex
# --------------------------------------------------------------------------- #

class WorkflowIndex:
    """O(1) uid → object routing tables for a live workflow.

    Replaces the bare ``task_index`` dict and the WFProcessor's linear
    ``_find_pipeline``/``_find_stage`` scans: a completion event resolves
    task → Stage object → Pipeline object in three dict lookups, so per-task
    completion routing is independent of the number of pipelines/stages
    (the paper's O(10⁴)-task scalability requirement).

    Stages appended at runtime by adaptive ``post_exec`` hooks are registered
    through :meth:`add_stage` when the WFProcessor first schedules them.
    """

    __slots__ = ("_tasks", "_stages", "_pipelines", "_lock")

    def __init__(self) -> None:
        self._tasks: Dict[str, Task] = {}
        self._stages: Dict[str, Stage] = {}
        self._pipelines: Dict[str, Pipeline] = {}
        self._lock = threading.RLock()

    # -- registration ------------------------------------------------------- #

    def add_pipeline(self, pipe: Pipeline) -> None:
        with self._lock:
            self._pipelines[pipe.uid] = pipe
            with pipe.lock:
                for stage in pipe.stages:
                    self._stages[stage.uid] = stage
                    for task in stage.tasks:
                        self._tasks[task.uid] = task

    def add_stage(self, stage: Stage) -> None:
        with self._lock:
            self._stages[stage.uid] = stage
            for task in stage.tasks:
                self._tasks[task.uid] = task

    def add_task(self, task: Task) -> None:
        with self._lock:
            self._tasks[task.uid] = task

    # -- O(1) lookups ------------------------------------------------------- #

    def task(self, uid: str) -> Optional[Task]:
        return self._tasks.get(uid)

    def stage(self, uid: str) -> Optional[Stage]:
        return self._stages.get(uid)

    def pipeline(self, uid: str) -> Optional[Pipeline]:
        return self._pipelines.get(uid)

    def stage_of(self, task: Task) -> Optional[Stage]:
        if task.parent_stage is None:
            return None
        return self._stages.get(task.parent_stage)

    def pipeline_of(self, task: Task) -> Optional[Pipeline]:
        if task.parent_pipeline is None:
            return None
        return self._pipelines.get(task.parent_pipeline)

    def route(self, uid: str
              ) -> "tuple[Optional[Task], Optional[Stage], Optional[Pipeline]]":
        """Resolve a completion uid to its (task, stage, pipeline) triple."""
        task = self._tasks.get(uid)
        if task is None:
            return None, None, None
        return task, self.stage_of(task), self.pipeline_of(task)

    # -- introspection ------------------------------------------------------ #

    @property
    def ntasks(self) -> int:
        return len(self._tasks)

    @property
    def nstages(self) -> int:
        return len(self._stages)

    @property
    def npipelines(self) -> int:
        return len(self._pipelines)

    def __len__(self) -> int:
        return len(self._tasks)
