"""Thread-safe unique-id factory.

EnTK names entities ``<kind>.%04d`` within a session; we keep that convention
because journal replay and the profiler key on uids. ``reset()`` exists only
for tests and benchmarks that want reproducible uids.
"""

from __future__ import annotations

import itertools
import threading
from collections import defaultdict
from typing import Dict, Iterator

_lock = threading.Lock()
_counters: Dict[str, Iterator[int]] = defaultdict(itertools.count)


def generate(kind: str) -> str:
    with _lock:
        return f"{kind}.{next(_counters[kind]):04d}"


def reset() -> None:
    with _lock:
        _counters.clear()
