"""In-process message broker with RabbitMQ delivery semantics.

EnTK uses a RabbitMQ server so that (1) producers/consumers are topology
unaware, (2) in-flight messages survive component failure, and (3) push/pull
are fully asynchronous (paper §II-C). Inside a single JAX controller process
the same contract is provided by named in-memory queues with explicit
acknowledgement and redelivery:

* ``put(queue, msg)`` — asynchronous publish (never blocks on consumers).
* ``get(queue, timeout)`` — returns ``(delivery_tag, msg)`` and holds the
  message *unacknowledged*; a consumer that dies without ``ack`` leaves the
  message eligible for redelivery via :meth:`requeue_unacked`.
* ``ack(queue, tag)`` — marks the message consumed.

The broker records counters used by the Fig.-6 prototype benchmark
(messages in/out, peak depth) and is intentionally dependency-free so that
the benchmark measures toolkit overhead, not library overhead.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .exceptions import ValueError_


class _Queue:
    __slots__ = ("name", "messages", "unacked", "cv", "put_count",
                 "get_count", "ack_count", "peak_depth")

    def __init__(self, name: str) -> None:
        self.name = name
        self.messages: deque = deque()
        self.unacked: Dict[int, Any] = {}
        self.cv = threading.Condition()
        self.put_count = 0
        self.get_count = 0
        self.ack_count = 0
        self.peak_depth = 0


class Broker:
    """A set of named queues with ack/redeliver semantics."""

    def __init__(self) -> None:
        self._queues: Dict[str, _Queue] = {}
        self._lock = threading.Lock()
        self._tags = itertools.count(1)
        self._closed = False

    # -- queue management ---------------------------------------------------#

    def declare(self, name: str) -> None:
        with self._lock:
            if name not in self._queues:
                self._queues[name] = _Queue(name)

    def delete(self, name: str) -> None:
        with self._lock:
            self._queues.pop(name, None)

    def queues(self) -> List[str]:
        with self._lock:
            return list(self._queues)

    def _q(self, name: str) -> _Queue:
        try:
            return self._queues[name]
        except KeyError:
            raise ValueError_(f"queue {name!r} not declared") from None

    # -- publish / consume ----------------------------------------------------#

    def put(self, name: str, msg: Any) -> None:
        q = self._q(name)
        with q.cv:
            q.messages.append(msg)
            q.put_count += 1
            depth = len(q.messages)
            if depth > q.peak_depth:
                q.peak_depth = depth
            q.cv.notify()

    def put_many(self, name: str, msgs: Iterable[Any]) -> None:
        q = self._q(name)
        with q.cv:
            before = len(q.messages)
            q.messages.extend(msgs)
            added = len(q.messages) - before
            q.put_count += added
            if len(q.messages) > q.peak_depth:
                q.peak_depth = len(q.messages)
            q.cv.notify_all()

    def get(self, name: str, timeout: Optional[float] = None
            ) -> Optional[Tuple[int, Any]]:
        """Pop one message; returns (delivery_tag, msg) or None on timeout."""
        q = self._q(name)
        deadline = None if timeout is None else time.monotonic() + timeout
        with q.cv:
            while not q.messages:
                if self._closed:
                    return None
                if deadline is None:
                    q.cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    q.cv.wait(remaining)
            msg = q.messages.popleft()
            tag = next(self._tags)
            q.unacked[tag] = msg
            q.get_count += 1
            return tag, msg

    def get_many(self, name: str, max_n: int, timeout: Optional[float] = None
                 ) -> List[Tuple[int, Any]]:
        """Batch pop of up to ``max_n`` messages (at least one, else [])."""
        first = self.get(name, timeout=timeout)
        if first is None:
            return []
        out = [first]
        q = self._q(name)
        with q.cv:
            while q.messages and len(out) < max_n:
                msg = q.messages.popleft()
                tag = next(self._tags)
                q.unacked[tag] = msg
                q.get_count += 1
                out.append((tag, msg))
        return out

    def ack(self, name: str, tag: int) -> None:
        q = self._q(name)
        with q.cv:
            q.unacked.pop(tag, None)
            q.ack_count += 1

    def requeue_unacked(self, name: str) -> int:
        """Redeliver every unacknowledged message (consumer-failure recovery)."""
        q = self._q(name)
        with q.cv:
            n = len(q.unacked)
            # preserve rough ordering: unacked messages go to the front
            for tag in sorted(q.unacked, reverse=True):
                q.messages.appendleft(q.unacked.pop(tag))
            q.cv.notify_all()
            return n

    # -- introspection --------------------------------------------------------#

    def depth(self, name: str) -> int:
        q = self._q(name)
        with q.cv:
            return len(q.messages)

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            qs = list(self._queues.values())
        return {
            q.name: {
                "put": q.put_count,
                "got": q.get_count,
                "acked": q.ack_count,
                "depth": len(q.messages),
                "unacked": len(q.unacked),
                "peak_depth": q.peak_depth,
            }
            for q in qs
        }

    def close(self) -> None:
        """Wake all blocked consumers; subsequent gets return None when empty."""
        self._closed = True
        with self._lock:
            qs = list(self._queues.values())
        for q in qs:
            with q.cv:
                q.cv.notify_all()
