"""In-process message broker with RabbitMQ delivery semantics.

EnTK uses a RabbitMQ server so that (1) producers/consumers are topology
unaware, (2) in-flight messages survive component failure, and (3) push/pull
are fully asynchronous (paper §II-C). Inside a single JAX controller process
the same contract is provided by named in-memory queues with explicit
acknowledgement and redelivery:

* ``put(queue, msg)`` — asynchronous publish (never blocks on consumers).
* ``get(queue, timeout)`` — returns ``(delivery_tag, msg)`` and holds the
  message *unacknowledged*; a consumer that dies without ``ack`` leaves the
  message eligible for redelivery via :meth:`requeue_unacked`.
* ``ack(queue, tag)`` — marks the message consumed.
* ``kick(queue)`` — wakes every consumer blocked on the queue *without*
  delivering a message (their ``get`` returns ``None``/``[]``). This is the
  event-driven core's wakeup channel: consumer loops block with
  ``timeout=None`` instead of sleep-polling, and producers of *state* (not
  messages) — task completions freeing slots, pilot resizes, component
  shutdown — kick the relevant queue so the consumer re-evaluates.
* ``get(..., abort=event)`` — a set ``abort`` event makes a blocked (or
  about-to-block) consumer return immediately; combined with ``kick`` this
  closes the set-stop-then-wake race without any polling timeout.

The broker records counters used by the Fig.-6 prototype benchmark
(messages in/out, peak depth) and is intentionally dependency-free so that
the benchmark measures toolkit overhead, not library overhead.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

from .exceptions import ValueError_


class _Queue:
    __slots__ = ("name", "messages", "unacked", "cv", "put_count",
                 "get_count", "ack_count", "peak_depth", "kick_pending")

    def __init__(self, name: str) -> None:
        self.name = name
        self.messages: deque = deque()
        self.unacked: Dict[int, Any] = {}
        self.cv = threading.Condition()
        self.put_count = 0
        self.get_count = 0
        self.ack_count = 0
        self.peak_depth = 0
        self.kick_pending = False


class Broker:
    """A set of named queues with ack/redeliver semantics."""

    def __init__(self) -> None:
        self._queues: Dict[str, _Queue] = {}
        self._lock = threading.Lock()
        self._tags = itertools.count(1)
        self._closed = False

    # -- queue management ---------------------------------------------------#

    def declare(self, name: str) -> None:
        with self._lock:
            if name not in self._queues:
                self._queues[name] = _Queue(name)

    def delete(self, name: str) -> None:
        with self._lock:
            self._queues.pop(name, None)

    def queues(self) -> List[str]:
        with self._lock:
            return list(self._queues)

    def _q(self, name: str) -> _Queue:
        try:
            return self._queues[name]
        except KeyError:
            raise ValueError_(f"queue {name!r} not declared") from None

    # -- publish / consume ----------------------------------------------------#

    def put(self, name: str, msg: Any) -> None:
        q = self._q(name)
        with q.cv:
            q.messages.append(msg)
            q.put_count += 1
            depth = len(q.messages)
            if depth > q.peak_depth:
                q.peak_depth = depth
            # Wake a consumer only on the empty→nonempty transition: while
            # messages are already pending, any sleeping consumer was
            # notified when the first one arrived and whoever is awake will
            # drain the rest. This collapses one-notify-per-message storms
            # (and their GIL handoffs) into one notify per idle period.
            if depth == 1:
                q.cv.notify()

    def put_many(self, name: str, msgs: Iterable[Any]) -> None:
        q = self._q(name)
        with q.cv:
            before = len(q.messages)
            q.messages.extend(msgs)
            added = len(q.messages) - before
            q.put_count += added
            if len(q.messages) > q.peak_depth:
                q.peak_depth = len(q.messages)
            if before == 0 and added:
                q.cv.notify_all()

    def get(self, name: str, timeout: Optional[float] = None,
            abort: Optional[threading.Event] = None
            ) -> Optional[Tuple[int, Any]]:
        """Pop one message; returns (delivery_tag, msg), or None on timeout,
        broker close, queue kick, or a set ``abort`` event."""
        q = self._q(name)
        deadline = None if timeout is None else time.monotonic() + timeout
        with q.cv:
            while not q.messages:
                if self._closed:
                    return None
                if q.kick_pending:
                    # kicks are latched, not edge-triggered: one delivered
                    # while the consumer was busy processing is consumed by
                    # its NEXT get, so capacity-change wakeups are never
                    # lost between blocking calls
                    q.kick_pending = False
                    return None
                if abort is not None and abort.is_set():
                    return None
                if deadline is None:
                    q.cv.wait()
                else:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return None
                    q.cv.wait(remaining)
            msg = q.messages.popleft()
            tag = next(self._tags)
            q.unacked[tag] = msg
            q.get_count += 1
            return tag, msg

    def get_many(self, name: str, max_n: int, timeout: Optional[float] = None,
                 abort: Optional[threading.Event] = None
                 ) -> List[Tuple[int, Any]]:
        """Batch pop of up to ``max_n`` messages (at least one, else [])."""
        first = self.get(name, timeout=timeout, abort=abort)
        if first is None:
            return []
        out = [first]
        q = self._q(name)
        with q.cv:
            while q.messages and len(out) < max_n:
                msg = q.messages.popleft()
                tag = next(self._tags)
                q.unacked[tag] = msg
                q.get_count += 1
                out.append((tag, msg))
        return out

    def kick(self, name: str) -> None:
        """Wake a consumer of ``name`` without a message: its current (or,
        if it is busy, its next) ``get`` returns None (``get_many`` → []).
        The kick is latched until consumed, so it is never lost to the
        window between two blocking calls."""
        q = self._q(name)
        with q.cv:
            q.kick_pending = True
            q.cv.notify_all()

    def ack(self, name: str, tag: int) -> None:
        q = self._q(name)
        with q.cv:
            q.unacked.pop(tag, None)
            q.ack_count += 1

    def ack_many(self, name: str, tags: Iterable[int]) -> None:
        """Acknowledge a batch under one lock acquisition (consumers that
        ack message-by-message measurably serialize their producers)."""
        q = self._q(name)
        with q.cv:
            for tag in tags:
                q.unacked.pop(tag, None)
                q.ack_count += 1

    def requeue_unacked(self, name: str) -> int:
        """Redeliver every unacknowledged message (consumer-failure recovery)."""
        q = self._q(name)
        with q.cv:
            n = len(q.unacked)
            # preserve rough ordering: unacked messages go to the front
            for tag in sorted(q.unacked, reverse=True):
                q.messages.appendleft(q.unacked.pop(tag))
            q.cv.notify_all()
            return n

    # -- introspection --------------------------------------------------------#

    def depth(self, name: str) -> int:
        q = self._q(name)
        with q.cv:
            return len(q.messages)

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            qs = list(self._queues.values())
        return {
            q.name: {
                "put": q.put_count,
                "got": q.get_count,
                "acked": q.ack_count,
                "depth": len(q.messages),
                "unacked": len(q.unacked),
                "peak_depth": q.peak_depth,
            }
            for q in qs
        }

    def close(self) -> None:
        """Wake all blocked consumers; subsequent gets return None when empty."""
        self._closed = True
        with self._lock:
            qs = list(self._queues.values())
        for q in qs:
            with q.cv:
                q.cv.notify_all()
