"""Seismic forward-ensemble workflow under EnTK (paper §IV-C.1, Fig. 10).

Each task forward-simulates one earthquake (one source position) on the
current velocity model. The scale experiment varies the *concurrency*
(pilot slots) for a fixed ensemble and injects failures at high concurrency
— reproducing the paper's observation that reducing concurrency eliminated
failures while EnTK's resubmission transparently completed the failed tasks
(157 attempted for 128 nominal at 2⁵ concurrency in the paper).
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import numpy as np

from ... import api
from ...core import AppManager, Pipeline, Stage, Task, register_executable
from ...fusion import fusable, fusable_reduction
from ...rts.base import ResourceDescription
from ...rts.jax_rts import JaxRTS
from ...rts.local import LocalRTS
from .solver import SeismicConfig, forward_simulation, make_velocity_model, misfit

_CACHE: Dict[str, object] = {}


def _forward_jit():
    if "fwd" not in _CACHE:
        _CACHE["fwd"] = jax.jit(forward_simulation,
                                static_argnames=("source_x", "cfg"))
    return _CACHE["fwd"]


def _velocity(kind: str, cfg: SeismicConfig, seed: int):
    key = ("vel", kind, cfg, seed)
    if key not in _CACHE:
        vel = make_velocity_model(cfg, kind, seed=seed)
        if isinstance(vel, jax.core.Tracer):
            # first call happened inside a trace (a fused vmap of
            # eval_misfit): the value is a traced constant — valid for
            # this trace, but caching it would leak the tracer into every
            # later scalar call
            return vel
        _CACHE[key] = vel
    return _CACHE[key]


def simulate_earthquake(source_x: int, nx: int = 96, nz: int = 96,
                        nt: int = 220, seed: int = 0) -> Dict[str, float]:
    """EnTK task executable: one forward simulation; returns summary stats
    (the seismogram itself would be staged out in production)."""
    cfg = SeismicConfig(nx=nx, nz=nz, nt=nt)
    vel = make_velocity_model(cfg, "true", seed=seed)
    seis = _forward_jit()(vel, source_x, cfg)
    seis.block_until_ready()
    return {"source_x": int(source_x),
            "energy": float((np.asarray(seis) ** 2).sum())}


register_executable("simulate_earthquake", simulate_earthquake)


@fusable(static_argnames=("nx", "nz", "nt", "seed", "dv"))
def eval_misfit(source_x: int, nx: int = 64, nz: int = 64, nt: int = 120,
                seed: int = 0, dv: float = 0.0):
    """EnTK task: the misfit of a trial (smooth background + ``dv``)
    velocity model against the true model's data for one earthquake — the
    fused seismic member kernel of the tomography workflow's evaluation
    sweep. ``source_x`` varies per member, so a fused micro-batch runs the
    whole source ensemble (observed-data forward + trial forward + misfit)
    as one batched scan over (B, nz, nx) wavefields.
    """
    import jax.numpy as jnp
    cfg = SeismicConfig(nx=nx, nz=nz, nt=nt)
    vel_true = _velocity("true", cfg, seed)
    vel_trial = _velocity("init", cfg, seed) + jnp.float32(dv)
    observed = forward_simulation(vel_true, source_x, cfg)
    return misfit(vel_trial, observed, source_x, cfg)


register_executable("eval_misfit", eval_misfit)


@fusable(static_argnames=("nx", "nz", "nt", "seed", "dv"))
def forward_trial(source_x: int, nx: int = 64, nz: int = 64, nt: int = 120,
                  seed: int = 0, dv: float = 0.0):
    """Chain link 1: the trial model's synthetic seismogram for one source.

    Split out of :func:`eval_misfit` so the evaluation sweep becomes an
    elementwise forward→misfit *chain*: per member the forward wavefield
    (the expensive link) hands its ``(nt, n_receivers)`` seismogram to the
    misfit link device-resident — under chain fusion the whole sweep runs
    both links as composed batched dispatches on one lease.
    """
    import jax.numpy as jnp
    cfg = SeismicConfig(nx=nx, nz=nz, nt=nt)
    vel_trial = _velocity("init", cfg, seed) + jnp.float32(dv)
    return forward_simulation(vel_trial, source_x, cfg)


register_executable("forward_trial", forward_trial)


@fusable(static_argnames=("nx", "nz", "nt", "seed"))
def trial_misfit(synthetic, source_x: int = 0, nx: int = 64, nz: int = 64,
                 nt: int = 120, seed: int = 0):
    """Chain link 2: L2 misfit of a trial seismogram against the observed
    data for its source (the observed forward is recomputed from the true
    model, exactly as :func:`eval_misfit` does — the two-link chain's
    values match the single-kernel sweep to float precision)."""
    import jax.numpy as jnp
    cfg = SeismicConfig(nx=nx, nz=nz, nt=nt)
    vel_true = _velocity("true", cfg, seed)
    observed = forward_simulation(vel_true, source_x, cfg)
    return 0.5 * jnp.sum((jnp.asarray(synthetic) - observed) ** 2)


register_executable("trial_misfit", trial_misfit)


def build_misfit_ensemble(n_events: int, *, nx: int = 64, nz: int = 64,
                          nt: int = 120, seed: int = 0, dv: float = 0.0,
                          max_retries: int = 0, fuse: bool = True
                          ) -> api.Ensemble:
    """The misfit-evaluation sweep as a declarative (fusible) ensemble."""
    xs = np.linspace(8, nx - 9, n_events).astype(int)
    return api.ensemble(
        eval_misfit,
        over=[{"source_x": int(sx), "nx": nx, "nz": nz, "nt": nt,
               "seed": seed, "dv": dv} for sx in xs],
        name=f"misfit-{seed}", max_retries=max_retries, fuse=fuse)


def build_misfit_chain(n_events: int, *, nx: int = 64, nz: int = 64,
                       nt: int = 120, seed: int = 0, dv: float = 0.0,
                       max_retries: int = 0, fuse: bool = True
                       ) -> api.Ensemble:
    """The misfit sweep as a 2-link forward→misfit chain (one member per
    earthquake source): ``api.compile`` detects the elementwise link and a
    chain-capable RTS executes each micro-batch through BOTH links as one
    composed dispatch, the per-source seismograms never touching the host."""
    xs = np.linspace(8, nx - 9, n_events).astype(int)
    forward = api.ensemble(
        forward_trial,
        over=[{"source_x": int(sx), "nx": nx, "nz": nz, "nt": nt,
               "seed": seed, "dv": dv} for sx in xs],
        name=f"forward-{seed}", max_retries=max_retries, fuse=fuse)
    return forward.then(
        trial_misfit,
        over=[{"source_x": int(sx), "nx": nx, "nz": nz, "nt": nt,
               "seed": seed} for sx in xs],
        name=f"misfit-chain-{seed}", max_retries=max_retries, fuse=fuse)


def run_misfit_chain(n_events: int, slots: int = 4, *, nx: int = 64,
                     nt: int = 120, seed: int = 0, dv: float = 0.0,
                     fuse: bool = True, chain: bool = True,
                     shard: bool = True, timeout: float = 600.0) -> Dict:
    """Evaluate the forward→misfit chain on the JaxRTS data plane.

    ``chain=False`` runs the identical 2-stage description per-stage-fused;
    ``fuse=False`` runs it member-per-task — the parity baselines. On a
    multi-device pool a wide event ensemble shards its chain across the
    whole mesh; ``shard=False`` pins it to per-device micro-batches."""
    ens = build_misfit_chain(n_events, nx=nx, nz=nx, nt=nt, seed=seed,
                             dv=dv, fuse=fuse)
    objective = api.gather(ens, total_misfit, name=f"total-chain-{seed}")
    t0 = time.time()
    result = api.run(
        objective, resources=ResourceDescription(slots=slots),
        rts_factory=lambda: JaxRTS(slot_oversubscribe=slots, shard=shard),
        chain=chain, shard=shard, timeout=timeout)
    elapsed = time.time() - t0
    out = {
        "n_events": n_events,
        "fused": fuse,
        "chained": chain,
        "all_done": result.all_done,
        "total_misfit": objective.out.result(),
        "misfits": [float(np.asarray(s.out.result())) for s in ens.specs],
        "wallclock_s": elapsed,
    }
    result.close()
    return out


@fusable_reduction(kind="sum")
def total_misfit(values: List) -> float:
    """Gather: the ensemble objective Σ_sources misfit(source).

    ``@fusable_reduction(kind="sum")`` lets ``api.compile`` fold this
    fan-in into the sweep's ``_fusion_dag`` plan: the whole
    forward → misfit → Σ aggregation becomes one device-side dispatch
    (sharded sweeps reduce via ``psum`` across the mesh), while the scalar
    body keeps running unchanged everywhere fusion is off."""
    return float(np.sum([np.asarray(v) for v in values]))


def run_misfit_ensemble(n_events: int, slots: int = 4, *, nx: int = 64,
                        nt: int = 120, seed: int = 0, dv: float = 0.0,
                        fuse: bool = True, shard: bool = True,
                        timeout: float = 600.0) -> Dict:
    """Evaluate the source-ensemble misfit on the fused JaxRTS path.

    ``fuse=False`` runs the identical description member-per-task — the
    scalar baseline the fusion benchmark and the parity tests compare
    against. ``shard=False`` keeps per-device micro-batches on
    multi-device inventories (a single-device run is unaffected).
    """
    ens = build_misfit_ensemble(n_events, nx=nx, nz=nx, nt=nt, seed=seed,
                                dv=dv, fuse=fuse)
    objective = api.gather(ens, total_misfit, name=f"total-misfit-{seed}")
    t0 = time.time()
    result = api.run(
        objective, resources=ResourceDescription(slots=slots),
        rts_factory=lambda: JaxRTS(slot_oversubscribe=slots, shard=shard),
        shard=shard, timeout=timeout)
    elapsed = time.time() - t0
    out = {
        "n_events": n_events,
        "fused": fuse,
        "all_done": result.all_done,
        "total_misfit": objective.out.result(),
        "misfits": [float(np.asarray(s.out.result())) for s in ens.specs],
        "wallclock_s": elapsed,
    }
    result.close()
    return out


def build_forward_ensemble(n_events: int, *, nx: int = 96, nz: int = 96,
                           nt: int = 220, max_retries: int = 3) -> Pipeline:
    pipe = Pipeline("seismic-forward")
    st = Stage("forward-simulations")
    xs = np.linspace(8, nx - 9, n_events).astype(int)
    for i, sx in enumerate(xs):
        st.add_tasks(Task(
            name=f"eq{i:03d}", executable="reg://simulate_earthquake",
            kwargs={"source_x": int(sx), "nx": nx, "nz": nz, "nt": nt},
            max_retries=max_retries, duration_hint=1.0))
    pipe.add_stages(st)
    return pipe


def run_forward_ensemble(n_events: int, concurrency: int,
                         failure_rate: float = 0.0, seed: int = 0,
                         nx: int = 96, nt: int = 220,
                         timeout: float = 600.0):
    """Fig.-10 cell: ``n_events`` forward sims on ``concurrency`` slots.

    ``failure_rate``: probability a task attempt fails (models the
    high-concurrency filesystem-overload failures of the paper); EnTK
    resubmits within each task's retry budget.
    """
    rng = np.random.default_rng(seed)
    attempts: Dict[str, int] = {}

    def injector(task) -> bool:
        attempts[task.name] = attempts.get(task.name, 0) + 1
        return bool(rng.random() < failure_rate)

    amgr = AppManager(
        resources=ResourceDescription(slots=concurrency),
        rts_factory=lambda: LocalRTS(fault_injector=injector))
    amgr.workflow = [build_forward_ensemble(n_events, nx=nx, nz=nx, nt=nt)]
    t0 = time.time()
    amgr.run(timeout=timeout)
    elapsed = time.time() - t0
    total_attempts = sum(attempts.values())
    return {
        "n_events": n_events,
        "concurrency": concurrency,
        "failure_rate": failure_rate,
        "all_done": amgr.all_done,
        "task_execution_s": amgr.prof.totals().get("task_execution", 0.0),
        "wallclock_s": elapsed,
        "attempts": total_attempts,
    }
