"""2-D acoustic full-waveform forward/adjoint solver in JAX.

Stands in for SPECFEM in the paper's tomography workflow (§III-A): the
physics is reduced (2-D acoustic, second-order FD leapfrog, absorbing-ish
damped boundaries) but the *workflow shape* is identical — per-earthquake
forward simulations producing seismograms at receiver arrays, a misfit
against observed data, and the adjoint gradient (here via ``jax.grad``
through the ``lax.scan`` time loop, which is exactly adjoint-state in
reverse-mode form) feeding an iterative velocity-model update.

Every function is jittable; forward simulations are the EnTK tasks of the
Fig.-10 scale experiment.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SeismicConfig:
    nx: int = 128
    nz: int = 128
    nt: int = 400
    dx: float = 10.0          # m
    dt: float = 1e-3          # s  (CFL: c_max·dt/dx < 1/√2)
    f0: float = 12.0          # Ricker peak frequency, Hz
    n_receivers: int = 32
    damp_width: int = 12
    damp_strength: float = 0.015


def make_velocity_model(cfg: SeismicConfig, kind: str = "true",
                        seed: int = 0) -> jnp.ndarray:
    """Layered background + (for 'true') an ellipsoidal anomaly."""
    z = np.linspace(0, 1, cfg.nz)[:, None]
    c = 1500.0 + 1200.0 * z + 0.0 * np.zeros((cfg.nz, cfg.nx))
    if kind == "true":
        rng = np.random.default_rng(seed)
        zz, xx = np.mgrid[0:cfg.nz, 0:cfg.nx]
        for _ in range(3):
            cz, cx = rng.uniform(0.3, 0.8) * cfg.nz, rng.uniform(
                0.2, 0.8) * cfg.nx
            rz, rx = rng.uniform(6, 14), rng.uniform(8, 20)
            blob = np.exp(-(((zz - cz) / rz) ** 2 + ((xx - cx) / rx) ** 2))
            c += rng.choice([-1, 1]) * 180.0 * blob
    return jnp.asarray(c, jnp.float32)


def _ricker(cfg: SeismicConfig) -> jnp.ndarray:
    t = jnp.arange(cfg.nt) * cfg.dt - 1.2 / cfg.f0
    a = (jnp.pi * cfg.f0 * t) ** 2
    return (1 - 2 * a) * jnp.exp(-a)


def _damping(cfg: SeismicConfig) -> jnp.ndarray:
    d = np.zeros((cfg.nz, cfg.nx))
    w = cfg.damp_width
    for i in range(w):
        val = cfg.damp_strength * ((w - i) / w) ** 2
        d[i, :] = np.maximum(d[i, :], val)
        d[-1 - i, :] = np.maximum(d[-1 - i, :], val)
        d[:, i] = np.maximum(d[:, i], val)
        d[:, -1 - i] = np.maximum(d[:, -1 - i], val)
    return jnp.asarray(d, jnp.float32)


def forward_simulation(velocity: jnp.ndarray, source_x: int,
                       cfg: SeismicConfig) -> jnp.ndarray:
    """One 'earthquake': source at (src_z=2, source_x). Returns the
    seismogram (nt, n_receivers) recorded at depth 2."""
    src = _ricker(cfg)
    damp = _damping(cfg)
    c2dt2 = (velocity * cfg.dt) ** 2 / (cfg.dx ** 2)
    rec_x = jnp.linspace(4, cfg.nx - 5, cfg.n_receivers).astype(jnp.int32)

    def laplacian(u):
        return (-4.0 * u
                + jnp.roll(u, 1, 0) + jnp.roll(u, -1, 0)
                + jnp.roll(u, 1, 1) + jnp.roll(u, -1, 1))

    def step(carry, s_t):
        u_prev, u = carry
        u_next = ((2.0 - damp) * u - (1.0 - damp) * u_prev
                  + c2dt2 * laplacian(u))
        u_next = u_next.at[2, source_x].add(s_t)
        rec = u_next[2, rec_x]
        return (u, u_next), rec

    shape = (cfg.nz, cfg.nx)
    (_, _), seis = jax.lax.scan(
        step, (jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)),
        src)
    return seis


def misfit(velocity: jnp.ndarray, observed: jnp.ndarray, source_x: int,
           cfg: SeismicConfig) -> jnp.ndarray:
    """L2 waveform misfit for one source."""
    synth = forward_simulation(velocity, source_x, cfg)
    return 0.5 * jnp.sum((synth - observed) ** 2)


def misfit_and_grad(velocity: jnp.ndarray, observed: jnp.ndarray,
                    source_x: int, cfg: SeismicConfig
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Adjoint gradient via reverse-mode through the time loop."""
    return jax.value_and_grad(misfit)(velocity, observed, source_x, cfg)
