"""Seismic inversion use case (paper §III-A / §IV-C.1)."""

from .solver import (SeismicConfig, forward_simulation, misfit_and_grad,  # noqa: F401
                     make_velocity_model)
from .workflow import build_forward_ensemble, run_forward_ensemble  # noqa: F401
