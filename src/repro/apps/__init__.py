"""Use-case applications from the paper (§III), implemented in JAX."""
