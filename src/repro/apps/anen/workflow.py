"""AUA (Adaptive Unstructured Analog) workflow under EnTK (§III-B, Fig. 11).

The iterative search is *described* on the declarative API
(:mod:`repro.api`): each iteration is an :func:`~repro.api.ensemble` over
location slices, and the unknown-length iteration sequence is an
:func:`~repro.api.repeat_until` loop — which the compiler lowers onto the
exact ``post_exec``/append-listener machinery the paper describes
(iteration stages appended at runtime, never re-entering an HPC queue).
Task *results* (the computed analog values) flow between rounds through the
API's data-flow plumbing instead of hand-scraping ``stage.tasks[i].result``.

Two implementations are compared, as in Fig. 11:

* **random** — each iteration computes analogs at uniformly random new
  locations;
* **AUA** — each iteration interpolates the current estimate, measures its
  local gradient, and places new locations preferentially where the field
  changes fastest (fronts), steering the computation.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from ... import api
from ...core import AppManager, register_executable
from ...fusion import fusable, fusable_reduction
from ...rts.base import ResourceDescription
from ...rts.jax_rts import JaxRTS
from .anen import (AnEnConfig, compute_analogs, gradient_magnitude,
                   idw_interpolate, make_dataset, rmse)

_DATASETS: Dict[int, object] = {}


def _dataset(seed: int, ny: int, nx: int, n_hist: int):
    key = (seed, ny, nx, n_hist)
    if key not in _DATASETS:
        import jax
        data = make_dataset(AnEnConfig(ny=ny, nx=nx, n_hist=n_hist,
                                       seed=seed))
        if any(isinstance(leaf, jax.core.Tracer) for leaf in data):
            # first call happened inside a trace (e.g. a fused vmap of
            # analog_values without its batched impl): valid for this
            # trace, but caching would leak tracers into later calls
            return data
        _DATASETS[key] = data
    return _DATASETS[key]


def _analog_values_batched(locations, *, seed: int, ny: int, nx: int,
                           n_hist: int, k: int):
    """Hand-batched implementation for the fusion engine: one dispatch for
    a whole micro-batch of members.

    ``locations`` is (B, n, 2) int32 — B members' (possibly padded)
    location slices. The member axis folds into the location axis (every
    location is independent), the similarity matrix runs through the
    Pallas distance kernel, and the analog means unfold back to (B, n).

    Traceability: the dataset fields go through ``jnp.asarray`` before the
    gather so the whole function jits — the SPMD sharded path runs it under
    ``jit(shard_map(...))`` with ``locations`` a tracer, and numpy arrays
    cannot be fancy-indexed by tracers.
    """
    import jax
    import jax.numpy as jnp
    from ...kernels.anen_distance import anen_distance

    data = _dataset(seed, ny, nx, n_hist)
    b, n, _ = locations.shape
    flat = locations.reshape(b * n, 2)
    ys, xs = flat[:, 0], flat[:, 1]
    f_now = jnp.asarray(data.forecast_now)[:, ys, xs]    # (V, B·n)
    f_h = jnp.asarray(data.hist_forecast)[:, :, ys, xs]  # (H, V, B·n)
    o_h = jnp.asarray(data.hist_obs)[:, ys, xs]          # (H, B·n)
    interpret = jax.default_backend() == "cpu"
    d2 = anen_distance(f_h, f_now, interpret=interpret)
    _, idx = jax.lax.top_k(-d2.T, k)                # (B·n, k) most similar
    picked = jnp.take_along_axis(o_h.T, idx, axis=1)
    return picked.mean(axis=1).reshape(b, n)


@fusable(static_argnames=("seed", "ny", "nx", "n_hist", "k"),
         pad_argnames=("locations",), batched=_analog_values_batched)
def analog_values(locations: List[List[int]], seed: int = 0, ny: int = 48,
                  nx: int = 48, n_hist: int = 120, k: int = 12):
    """EnTK task: analog predictions at a slice of locations — the fused
    AnEn member kernel. Scalar execution (LocalRTS, or a group below the
    fusion threshold) computes exactly the same values through
    :func:`compute_analogs`; fused execution batches congruent members into
    one dispatch with the Pallas distance kernel."""
    import jax.numpy as jnp
    data = _dataset(seed, ny, nx, n_hist)
    locs = jnp.asarray(locations, jnp.int32)
    return compute_analogs(data, locs, k)


register_executable("analog_values", analog_values)


@fusable(static_argnames=("lo", "hi"), pad_argnames=("values",))
def analog_refine(values, lo: float = 0.0, hi: float = 1.0):
    """Second chain link of each round: bound the analog estimates to the
    historical observation range.

    Analog means are averages of observed values, so the clip is exactly
    the identity on well-formed inputs — it is a guard against corrupted
    history windows, and (deliberately) keeps the fused/chained rounds
    bit-identical to the scalar path. What it buys structurally: every
    AnEn round is now a 2-link elementwise chain (``analog_values →
    analog_refine``), so a chain-capable RTS runs the whole round's
    micro-batches as composed dispatches with the raw analog values never
    leaving the device between the links.
    """
    import jax.numpy as jnp
    return jnp.clip(jnp.asarray(values, jnp.float32), lo, hi)


register_executable("analog_refine", analog_refine)


@fusable_reduction(kind="max")
def round_spread(values) -> float:
    """Round fan-in: the largest analog estimate of the round — a cheap
    convergence statistic (the adaptive criterion watches the estimate's
    dynamic range tighten as fronts get resolved).

    ``kind="max"`` makes the whole round a fusable DAG
    (``analog_values → analog_refine → max``): a DAG-capable RTS runs one
    composed dispatch per round, with the reduction executing device-side
    over the refined member values (``psum``-free — max is also safe over
    the engine's edge-replicated pad rows). Scalar execution keeps the
    plain ``np.max`` body bit-for-bit.
    """
    return float(np.max([np.max(np.asarray(v)) for v in values]))


register_executable("round_spread", round_spread)


class _RoundNode(api.Node):
    """What :meth:`_SearchState.make_round` returns: the refine ensemble's
    member futures PLUS the round's spread reduction. The loop's check
    stage collects all of them (``absorb`` zips results against the round's
    location slices, so the trailing spread value is simply extra), while
    the gather's presence is what turns the round into a fusable DAG."""

    def __init__(self, refine: api.Ensemble, spread) -> None:
        self.refine = refine
        self.spread = spread

    def futures(self):
        return list(self.refine.futures()) + list(self.spread.futures())


class _SearchState:
    """Shared state the adaptive post_exec hooks steer."""

    def __init__(self, method: str, seed: int, cfg: AnEnConfig,
                 per_iter: int, max_iters: int, n_tasks: int,
                 fuse: bool = True) -> None:
        self.method = method
        self.seed = seed
        self.cfg = cfg
        self.per_iter = per_iter
        self.max_iters = max_iters
        self.n_tasks = n_tasks
        self.fuse = fuse
        self.rng = np.random.default_rng(seed + (0 if method == "aua"
                                                 else 10_000))
        self.locations: List[List[int]] = []
        self.values: List[float] = []
        self.errors: List[float] = []
        self.iteration = 0
        self.data = _dataset(seed, cfg.ny, cfg.nx, cfg.n_hist)
        # bounds for the refine link (the historical observation range):
        # plain floats, so they ride the chain as static arguments
        obs = np.asarray(self.data.hist_obs)
        self.obs_lo = float(obs.min())
        self.obs_hi = float(obs.max())
        # the location slices of the round in flight: member results come
        # back as bare value arrays (device-resident on the fused path), so
        # the builder keeps the location bookkeeping host-side
        self._round_slices: List[List[List[int]]] = []

    # ---- location proposal ------------------------------------------------ #

    def initial_locations(self) -> np.ndarray:
        return self._random_new(self.per_iter)

    def _random_new(self, n: int) -> np.ndarray:
        taken = set(map(tuple, self.locations))
        out = []
        while len(out) < n:
            y = int(self.rng.integers(0, self.cfg.ny))
            x = int(self.rng.integers(0, self.cfg.nx))
            if (y, x) not in taken:
                taken.add((y, x))
                out.append([y, x])
        return np.asarray(out, np.int32)

    def _adaptive_new(self, n: int) -> np.ndarray:
        """AUA refinement: greedy picks by error-indicator × spacing.

        priority(cell) = |∇ estimate| × dist²-to-nearest-sample — the
        classical adaptive-mesh criterion: refine where the field changes
        fast *and* the sampling is still coarse. Greedy selection with
        neighbourhood suppression avoids redundant clustering on the same
        front pixel. A quarter of the budget stays uniform (coverage of
        regions the current estimate cannot see yet).
        """
        import jax.numpy as jnp
        n_explore = max(1, n // 4)
        n_exploit = n - n_explore
        explore = self._random_new(n_explore)
        ny, nx = self.cfg.ny, self.cfg.nx
        locs = jnp.asarray(self.locations, jnp.int32)
        vals = jnp.asarray(self.values, jnp.float32)
        est = idw_interpolate(locs, vals, ny, nx)
        grad = np.asarray(gradient_magnitude(est)).astype(np.float64)
        # smear the indicator one cell so line-like fronts are 2-3 px wide
        grad = grad + 0.5 * (np.roll(grad, 1, 0) + np.roll(grad, -1, 0)
                             + np.roll(grad, 1, 1) + np.roll(grad, -1, 1))
        yy, xx = np.mgrid[0:ny, 0:nx]
        all_pts = (np.asarray(self.locations + explore.tolist())
                   if len(self.locations) else explore)
        d2 = np.full((ny, nx), np.inf)
        for (py, px) in all_pts:
            d2 = np.minimum(d2, (yy - py) ** 2 + (xx - px) ** 2)
        picks = []
        pri = grad * d2
        for _ in range(n_exploit):
            flat = int(np.argmax(pri))
            py, px = flat // nx, flat % nx
            picks.append([py, px])
            nd2 = (yy - py) ** 2 + (xx - px) ** 2
            d2 = np.minimum(d2, nd2)
            pri = grad * d2
        return np.concatenate([explore, np.asarray(picks, np.int32)],
                              axis=0)

    def propose(self, n: int) -> np.ndarray:
        if self.method == "aua" and self.iteration > 0:
            return self._adaptive_new(n)
        return self._random_new(n)

    # ---- bookkeeping ------------------------------------------------------- #

    def absorb(self, results: List) -> None:
        """Fold one round's task results (analog values) into the estimate.

        ``results`` line up with the round's location slices by member
        index; each value may be a list, ndarray, or a device-resident
        :class:`~repro.fusion.ArrayResult` — ``np.asarray`` reads them all.
        """
        for slice_locs, r in zip(self._round_slices, results):
            if r is None:
                continue
            self.locations.extend(slice_locs)
            self.values.extend(np.asarray(r).tolist())
        import jax.numpy as jnp
        locs = jnp.asarray(self.locations, jnp.int32)
        vals = jnp.asarray(self.values, jnp.float32)
        est = idw_interpolate(locs, vals, self.cfg.ny, self.cfg.nx)
        self.errors.append(rmse(est, self.data.truth))
        self.iteration += 1

    # ---- declarative description ------------------------------------------- #

    def make_round(self, ctx: api.LoopContext) -> api.Node:
        """One iteration: a fusable DAG over location slices
        (``analog_values → analog_refine → max``, elementwise between the
        first two links, whole-round fan-in at the spread gather).

        ``ctx.results`` (the previous round's values) were absorbed by
        :meth:`converged` before this builder runs, so proposals always see
        the up-to-date estimate — including on journal resume, where rounds
        replay in order through the same two hooks. DAG/chain detection
        runs when the round is planned at runtime, so every adaptive round
        gets the composed-dispatch data plane — a DAG-capable RTS executes
        the whole round (both links plus the device-side reduction) as ONE
        dispatch — not just static workflows.
        """
        locs = self.propose(self.per_iter)
        slices = [sl for sl in np.array_split(locs, self.n_tasks)
                  if len(sl)]
        self._round_slices = [sl.tolist() for sl in slices]
        search = api.ensemble(
            analog_values,
            over=[{"seed": self.seed, "ny": self.cfg.ny, "nx": self.cfg.nx,
                   "n_hist": self.cfg.n_hist, "k": self.cfg.k,
                   "locations": sl.tolist()} for sl in slices],
            name=f"{self.method}-it{ctx.round}-{self.seed}",
            max_retries=1, fuse=self.fuse)
        refine = search.then(
            analog_refine,
            over=[{"lo": self.obs_lo, "hi": self.obs_hi} for _ in slices],
            name=f"{self.method}-it{ctx.round}-{self.seed}-ref",
            max_retries=1, fuse=self.fuse)
        spread = api.gather(
            refine, round_spread,
            name=f"{self.method}-it{ctx.round}-{self.seed}-spread")
        return _RoundNode(refine, spread)

    def converged(self, ctx: api.LoopContext) -> bool:
        """repeat_until predicate: absorb the finished round, then decide."""
        self.absorb(ctx.results)
        return self.iteration >= self.max_iters

    def as_loop(self) -> api.Loop:
        return api.repeat_until(
            self.converged, self.make_round,
            name=f"anen-{self.method}-{self.seed}",
            max_rounds=self.max_iters)


def _run(method: str, seed: int, *, ny: int, nx: int, n_hist: int,
         per_iter: int, max_iters: int, n_tasks: int, slots: int,
         timeout: float, fuse: bool = True, shard: bool = True) -> Dict:
    cfg = AnEnConfig(ny=ny, nx=nx, n_hist=n_hist, seed=seed)
    search = _SearchState(method, seed, cfg, per_iter, max_iters, n_tasks,
                          fuse=fuse)
    amgr = AppManager(resources=ResourceDescription(slots=slots),
                      # the fused path: congruent analog members of one
                      # round batch into a single dispatch on the device
                      # pool (fuse=False or a LocalRTS factory reproduces
                      # the per-task scalar behaviour bit-for-bit). On a
                      # multi-device pool a wide round shards across the
                      # whole mesh (shard=False opts out)
                      rts_factory=lambda: JaxRTS(slot_oversubscribe=slots,
                                                 shard=shard),
                      heartbeat_interval=1.0)
    compiled = api.compile(search.as_loop(), name=f"anen-{method}-{seed}")
    amgr.workflow = compiled
    amgr.run(timeout=timeout)
    if compiled.hook_errors:
        raise RuntimeError(f"anen adaptive hooks failed: "
                           f"{compiled.hook_errors}")
    # everything we report lives in the search state; release the store
    # namespace so repeated runs (compare_methods sweeps) stay bounded
    compiled.close()
    return {"method": method, "seed": seed,
            "n_locations": len(search.locations),
            "rounds": search.iteration,
            "errors": search.errors, "final_rmse": search.errors[-1],
            "all_done": amgr.all_done}


def run_adaptive(seed: int = 0, **kw) -> Dict:
    return _run("aua", seed, **_defaults(kw))


def run_random(seed: int = 0, **kw) -> Dict:
    return _run("random", seed, **_defaults(kw))


def _defaults(kw: Dict) -> Dict:
    out = dict(ny=48, nx=48, n_hist=120, per_iter=60, max_iters=5,
               n_tasks=4, slots=4, timeout=600.0)
    out.update(kw)
    return out


def compare_methods(repeats: int = 5, **kw) -> Dict:
    """Fig.-11 comparison: error distributions over repeated runs."""
    aua, rnd = [], []
    for r in range(repeats):
        aua.append(run_adaptive(seed=r, **kw)["final_rmse"])
        rnd.append(run_random(seed=r, **kw)["final_rmse"])
    return {
        "repeats": repeats,
        "aua_rmse": aua,
        "random_rmse": rnd,
        "aua_median": float(np.median(aua)),
        "random_median": float(np.median(rnd)),
        "aua_wins": int(sum(a < b for a, b in zip(aua, rnd))),
    }
