"""Analog-Ensemble forecasting use case (paper §III-B / §IV-C.2)."""

from .anen import (AnEnConfig, AnEnData, make_dataset, compute_analogs,  # noqa: F401
                   idw_interpolate, rmse)
from .workflow import run_adaptive, run_random, compare_methods  # noqa: F401
