"""Analog Ensemble (AnEn) numerics in JAX.

Monache-style analog forecasting: for a target time and location, find the
``k`` historical forecasts most similar to the current forecast (similarity
over a short time window and multiple variables) and average their verified
observations. The paper's AUA contribution is *where* to compute analogs:
adaptively concentrating locations where the field has sharp gradients
instead of sampling uniformly (§III-B, Fig. 11).

Synthetic NAM-like dataset: a truth field with smooth structure plus sharp
fronts; historical forecast/observation pairs share a stationary,
spatially-correlated error process, so analog search is genuinely
informative (forecasts with similar values have similar errors).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class AnEnConfig:
    ny: int = 64
    nx: int = 64
    n_hist: int = 200        # historical forecast/observation pairs
    n_vars: int = 3          # forecast variables entering the similarity
    k: int = 12              # analogs averaged
    seed: int = 0


class AnEnData(NamedTuple):
    truth: jnp.ndarray          # (ny, nx) — verification field O_now
    forecast_now: jnp.ndarray   # (n_vars, ny, nx)
    hist_forecast: jnp.ndarray  # (n_hist, n_vars, ny, nx)
    hist_obs: jnp.ndarray       # (n_hist, ny, nx)


def _smooth_noise(rng, shape, scale: int) -> np.ndarray:
    """Cheap spatially-correlated noise: upsampled coarse white noise."""
    coarse = rng.standard_normal((shape[0] // scale + 2,
                                  shape[1] // scale + 2))
    up = np.kron(coarse, np.ones((scale, scale)))
    out = up[:shape[0], :shape[1]]
    # light box blur
    for _ in range(2):
        out = 0.25 * (np.roll(out, 1, 0) + np.roll(out, -1, 0)
                      + np.roll(out, 1, 1) + np.roll(out, -1, 1))
    return out


def make_dataset(cfg: AnEnConfig) -> AnEnData:
    rng = np.random.default_rng(cfg.seed)
    ny, nx = cfg.ny, cfg.nx
    yy, xx = np.mgrid[0:ny, 0:nx] / max(ny, nx)
    # truth: smooth waves + two sharp fronts (the AUA refinement targets)
    base = (np.sin(2.5 * np.pi * xx) * np.cos(1.5 * np.pi * yy)
            + 0.5 * np.sin(4 * np.pi * (xx + yy)))
    front = (np.tanh(18 * (yy - 0.45 - 0.18 * np.sin(3 * np.pi * xx)))
             + 0.7 * np.tanh(24 * (xx - 0.7 + 0.1 * np.cos(2 * np.pi * yy))))
    # front-dominated, as in the paper's temperature maps: "the highest
    # resolution of the analogs is required only at specific regions,
    # where drastic gradient changes occur"
    truth = 0.35 * base + 2.2 * front

    def day_field(t: int) -> np.ndarray:
        season = 0.6 * np.sin(2 * np.pi * t / 73.0)
        wobble = _smooth_noise(np.random.default_rng(cfg.seed + 100 + t),
                               (ny, nx), 8) * 0.35
        return truth + season + wobble

    hist_obs = np.stack([day_field(t) for t in range(cfg.n_hist)])
    # forecast error process: stationary spatially-correlated bias + noise
    bias = _smooth_noise(rng, (ny, nx), 16) * 0.5
    def forecast_of(obs, t):
        r = np.random.default_rng(cfg.seed + 500 + t)
        err = bias + _smooth_noise(r, (ny, nx), 8) * 0.3
        f0 = obs + err
        # extra predictor variables: shifted/scaled views with their own noise
        f1 = 0.8 * obs + 0.3 + _smooth_noise(r, (ny, nx), 8) * 0.25
        f2 = np.roll(obs, 2, axis=1) + _smooth_noise(r, (ny, nx), 8) * 0.3
        return np.stack([f0, f1, f2][:3])

    hist_forecast = np.stack(
        [forecast_of(hist_obs[t], t) for t in range(cfg.n_hist)])
    obs_now = day_field(cfg.n_hist + 13)
    forecast_now = forecast_of(obs_now, cfg.n_hist + 13)
    return AnEnData(
        truth=jnp.asarray(obs_now, jnp.float32),
        forecast_now=jnp.asarray(forecast_now, jnp.float32),
        hist_forecast=jnp.asarray(hist_forecast, jnp.float32),
        hist_obs=jnp.asarray(hist_obs, jnp.float32),
    )


def compute_analogs(data: AnEnData, locations: jnp.ndarray, k: int
                    ) -> jnp.ndarray:
    """AnEn prediction at ``locations`` (n, 2) int32 (y, x) indices.

    similarity(h, p) = Σ_vars w_v · (F_now[v,p] − F_hist[h,v,p])²  (lower
    is more similar); prediction = mean of the k most similar historical
    observations at p.
    """
    ys, xs = locations[:, 0], locations[:, 1]
    f_now = data.forecast_now[:, ys, xs]            # (V, n)
    f_h = data.hist_forecast[:, :, ys, xs]          # (H, V, n)
    o_h = data.hist_obs[:, ys, xs]                  # (H, n)
    d2 = jnp.sum((f_h - f_now[None]) ** 2, axis=1)  # (H, n)
    _, idx = jax.lax.top_k(-d2.T, k)                # (n, k) most similar
    picked = jnp.take_along_axis(o_h.T, idx, axis=1)
    return picked.mean(axis=1)                      # (n,)


def idw_interpolate(locations: jnp.ndarray, values: jnp.ndarray,
                    ny: int, nx: int, power: float = 2.0,
                    k_nearest: int = 8, eps: float = 1e-6) -> jnp.ndarray:
    """k-nearest inverse-distance interpolation onto the full grid.

    Restricting to the nearest ``k`` samples (the unstructured-grid
    behaviour of the paper's implementation) is what makes *local*
    refinement effective: far-away samples cannot wash out a freshly
    refined front.
    """
    yy, xx = jnp.mgrid[0:ny, 0:nx]
    gy = yy.reshape(-1).astype(jnp.float32)
    gx = xx.reshape(-1).astype(jnp.float32)
    ly = locations[:, 0].astype(jnp.float32)
    lx = locations[:, 1].astype(jnp.float32)
    d2 = ((gy[:, None] - ly[None]) ** 2
          + (gx[:, None] - lx[None]) ** 2)          # (G, n)
    k = min(k_nearest, d2.shape[1])
    neg_d2, idx = jax.lax.top_k(-d2, k)             # (G, k) nearest
    w = 1.0 / ((-neg_d2) ** (power / 2) + eps)
    vals = values[idx]                               # (G, k)
    est = (w * vals).sum(axis=1) / w.sum(axis=1)
    return est.reshape(ny, nx)


def rmse(a: jnp.ndarray, b: jnp.ndarray) -> float:
    return float(jnp.sqrt(jnp.mean((a - b) ** 2)))


def gradient_magnitude(field: jnp.ndarray) -> jnp.ndarray:
    gy = jnp.abs(jnp.roll(field, -1, 0) - field)
    gx = jnp.abs(jnp.roll(field, -1, 1) - field)
    return gy + gx
