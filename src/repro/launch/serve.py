"""Serving driver: batched prefill + decode under EnTK management.

Each request batch is an EnTK task (``reg://serve_batch``): prefill the
prompt batch, then decode ``max_new_tokens`` greedily. Failed batches are
resubmitted by the toolkit — serving inherits the same fault-tolerance
contract as training.
"""

from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AppManager, Pipeline, Stage, Task, register_executable
from repro.rts.base import ResourceDescription
from repro.rts.jax_rts import JaxRTS
from repro.models import steps as steps_mod, transformer
from repro.models.config import get_config

_SESSIONS: Dict[str, "ServeSession"] = {}


class ServeSession:
    def __init__(self, arch: str, smoke: bool = True,
                 max_len: int = 256) -> None:
        self.cfg = get_config(arch, smoke=smoke)
        self.max_len = max_len
        self.params = transformer.init_params(
            self.cfg, jax.random.PRNGKey(0), jnp.bfloat16)
        self.prefill = jax.jit(steps_mod.make_prefill_step(self.cfg))
        self.decode = jax.jit(steps_mod.make_decode_step(self.cfg))

    def generate(self, prompts: np.ndarray, max_new_tokens: int = 16
                 ) -> np.ndarray:
        """prompts: (B, S) int32 → (B, max_new_tokens) int32 greedy."""
        cfg = self.cfg
        B, S = prompts.shape
        batch = {"inputs": jnp.asarray(prompts, jnp.int32)}
        if cfg.rope_variant == "mrope":
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
            batch["positions"] = jnp.broadcast_to(pos[:, None], (B, 3, S))
        logits, cache = self.prefill(self.params, batch)
        # move prefill cache into a max_len cache
        full = transformer.init_cache(cfg, B, S + max_new_tokens)
        full = _merge_cache(full, cache)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out = [tok]
        for _ in range(max_new_tokens - 1):
            logits, full = self.decode(self.params, tok, full)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            out.append(tok)
        return np.asarray(jnp.concatenate(out, axis=1))


def _merge_cache(dst, src):
    if isinstance(dst, dict):
        return {k: _merge_cache(dst[k], src[k]) if k in src else dst[k]
                for k in dst}
    if dst.shape == src.shape:
        return src.astype(dst.dtype)
    sl = tuple(slice(0, s) for s in src.shape)
    return dst.at[sl].set(src.astype(dst.dtype))


def get_session(arch: str, smoke: bool = True) -> ServeSession:
    key = f"{arch}:{smoke}"
    if key not in _SESSIONS:
        _SESSIONS[key] = ServeSession(arch, smoke)
    return _SESSIONS[key]


def serve_batch(arch: str, smoke: bool, prompts: List[List[int]],
                max_new_tokens: int = 8) -> List[List[int]]:
    sess = get_session(arch, smoke)
    out = sess.generate(np.asarray(prompts, np.int32), max_new_tokens)
    return out.tolist()


register_executable("serve_batch", serve_batch)


def run_managed(arch: str, n_batches: int = 4, batch_size: int = 4,
                prompt_len: int = 16, max_new_tokens: int = 8,
                smoke: bool = True) -> AppManager:
    """Serve ``n_batches`` request batches as one EnTK stage (concurrent)."""
    rng = np.random.default_rng(0)
    cfg = get_config(arch, smoke=smoke)
    pipe = Pipeline(f"serve-{arch}")
    st = Stage("requests")
    for b in range(n_batches):
        prompts = rng.integers(
            0, cfg.vocab_size, (batch_size, prompt_len)).tolist()
        st.add_tasks(Task(
            name=f"batch{b}", executable="reg://serve_batch",
            kwargs={"arch": arch, "smoke": smoke, "prompts": prompts,
                    "max_new_tokens": max_new_tokens},
            max_retries=1))
    pipe.add_stages(st)
    amgr = AppManager(resources=ResourceDescription(slots=2),
                      rts_factory=JaxRTS)
    amgr.workflow = [pipe]
    amgr.run(timeout=600)
    return amgr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-vl-2b")
    ap.add_argument("--batches", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()
    arch = args.arch
    cfg = get_config(arch, smoke=True)
    if cfg.embedding_inputs:
        print(f"{arch} takes embedding inputs; using token-input arch "
              "stablelm-12b for the CLI demo")
        arch = "stablelm-12b"
    t0 = time.time()
    amgr = run_managed(arch, n_batches=args.batches,
                       batch_size=args.batch_size,
                       max_new_tokens=args.new_tokens)
    results = [t.result for p in amgr.workflow
               for s in p.stages for t in s.tasks]
    print(f"served {len(results)} batches in {time.time()-t0:.1f}s; "
          f"all DONE: {amgr.all_done}")
    print("sample generation:", results[0][0] if results[0] else None)


if __name__ == "__main__":
    main()
