import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (including
# `from repro...`): JAX locks the device count on first initialization, and
# the production meshes below need 512 placeholder host devices. Only the
# dry-run sets this — smoke tests and benchmarks see the real single device.

"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:

    with mesh:
        lowered = jax.jit(step, in_shardings=…, out_shardings=…,
                          donate_argnums=…).lower(**input_specs(arch))
        compiled = lowered.compile()
        compiled.memory_analysis()   # proves the cell fits per-device HBM
        compiled.cost_analysis()     # XLA's own counters (recorded raw)
        analyze(compiled.as_text())  # trip-count-correct roofline terms

Results are appended as JSON-lines to ``results/dryrun.jsonl`` (consumed by
``benchmarks/roofline.py`` and EXPERIMENTS.md).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx-132b \
        --shape train_4k --multi-pod both
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.models.config import SHAPES, get_config, list_archs
from repro.models import input_specs as ispec
from repro.models import sharding as shd
from repro.models import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze, roofline_terms
from repro.models.pspec_ctx import activation_ctx


def _mesh_devices(mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               cfg_overrides: Optional[Dict[str, Any]] = None):
    """Build (lowered, meta) for one cell. Raises on sharding bugs."""
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    cfg = cfg.replace(kv_repeat=shd.kv_repeat_for(cfg, mesh),
                      **(cfg_overrides or {}))
    specs = ispec.input_specs(cfg, shape)
    p_pspecs = shd.param_specs(cfg, mesh)

    with mesh, activation_ctx(mesh, param_pspecs=p_pspecs):
        if shape.kind == "train":
            state_specs = shd.named(mesh, shd.train_state_specs(cfg, mesh))
            batch_sh = shd.named(mesh, shd.batch_pspecs(cfg, shape, mesh))
            abstract_state = steps_mod.abstract_train_state(cfg)
            fn = steps_mod.make_train_step(cfg)
            metric_specs = jax.tree.map(
                lambda _: shd.named(mesh, jax.sharding.PartitionSpec()),
                {"loss": 0, "ce": 0, "aux": 0, "grad_norm": 0, "lr": 0})
            jitted = jax.jit(
                fn,
                in_shardings=(state_specs, batch_sh),
                out_shardings=(state_specs, metric_specs),
                donate_argnums=(0,))
            lowered = jitted.lower(abstract_state, specs["batch"])
        elif shape.kind == "prefill":
            p_specs = shd.named(mesh, shd.param_specs(cfg, mesh))
            batch_sh = shd.named(mesh, shd.batch_pspecs(cfg, shape, mesh))
            abstract_params = steps_mod.transformer.abstract_params(cfg)
            fn = steps_mod.make_prefill_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(p_specs, batch_sh)).lower(
                abstract_params, specs["batch"])
        else:  # decode
            p_specs = shd.named(mesh, shd.param_specs(cfg, mesh))
            cache_sh = shd.named(mesh, shd.cache_pspecs(cfg, shape, mesh))
            tok_sh = shd.named(mesh, shd.token_pspec(cfg, shape, mesh))
            abstract_params = steps_mod.transformer.abstract_params(cfg)
            fn = steps_mod.make_decode_step(cfg)
            logits_spec = shd.named(
                mesh, jax.sharding.PartitionSpec(None, "model"))
            jitted = jax.jit(
                fn,
                in_shardings=(p_specs, tok_sh, cache_sh),
                out_shardings=(logits_spec, cache_sh),
                donate_argnums=(2,))
            lowered = jitted.lower(abstract_params, specs["token"],
                                   specs["cache"])
    meta = {"arch": arch, "shape": shape_name,
            "multi_pod": multi_pod, "mesh": dict(mesh.shape),
            "kind": shape.kind, "kv_repeat": cfg.kv_repeat,
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params()}
    return lowered, meta, mesh, cfg, shape


def compile_cell(arch: str, shape_name: str, multi_pod: bool = False,
                 verbose: bool = True,
                 cfg_overrides: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
    """Lower + compile one cell and extract all dry-run artifacts."""
    t0 = time.time()
    lowered, meta, mesh, cfg, shape = lower_cell(
        arch, shape_name, multi_pod, cfg_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    hlo = analyze(compiled.as_text())
    n_dev = _mesh_devices(mesh)

    record: Dict[str, Any] = {
        **meta,
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "xla_cost_analysis": {k: cost.get(k) for k in
                              ("flops", "bytes accessed")},
        "per_device": {
            "flops": hlo["flops"],
            "bytes": hlo["bytes"],
            "collective_bytes": hlo["collective_bytes"],
        },
        "collective_detail": hlo["collective_detail"],
        "roofline": roofline_terms(hlo),
        "n_devices": n_dev,
    }
    # MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); D = trained tokens.
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * meta["n_active_params"] * tokens
        # backward≈2× forward already included in the 6·N·D convention
        record["model_flops"] = model_flops
        record["model_flops_per_device"] = model_flops / n_dev
        record["useful_flops_ratio"] = (
            model_flops / n_dev / max(1.0, hlo["flops"]))
    else:
        tokens = (shape.global_batch if shape.kind == "decode"
                  else shape.global_batch * shape.seq_len)
        model_flops = 2.0 * meta["n_active_params"] * tokens
        record["model_flops"] = model_flops
        record["model_flops_per_device"] = model_flops / n_dev
        record["useful_flops_ratio"] = (
            model_flops / n_dev / max(1.0, hlo["flops"]))
    if verbose:
        r = record["roofline"]
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}: "
              f"compile {t_compile:.1f}s  "
              f"peak/dev {record['memory']['peak_bytes_per_device']/2**30:.2f} GiB  "
              f"t_comp {r['t_compute']*1e3:.2f}ms  "
              f"t_mem {r['t_memory']*1e3:.2f}ms  "
              f"t_coll {r['t_collective']*1e3:.2f}ms  "
              f"dominant={r['dominant']}  "
              f"useful={record['useful_flops_ratio']:.2f}")
    return record


def run_cells(archs, shapes, multi_pod_modes, out_path: str,
              stop_on_error: bool = False) -> int:
    failures = 0
    os.makedirs(os.path.dirname(os.path.abspath(out_path)), exist_ok=True)
    with open(out_path, "a") as fh:
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                if (shape_name == "long_500k" and not cfg.sub_quadratic):
                    rec = {"arch": arch, "shape": shape_name, "ok": None,
                           "skipped": ("full-attention arch: no "
                                       "sub-quadratic path at 524288 ctx")}
                    fh.write(json.dumps(rec) + "\n")
                    fh.flush()
                    print(f"[dryrun] {arch} × {shape_name}: SKIP "
                          f"(full attention; see DESIGN.md)")
                    continue
                for mp in multi_pod_modes:
                    try:
                        rec = compile_cell(arch, shape_name, multi_pod=mp)
                    except Exception as e:  # noqa: BLE001
                        failures += 1
                        rec = {"arch": arch, "shape": shape_name,
                               "multi_pod": mp, "ok": False,
                               "error": f"{type(e).__name__}: {e}"}
                        print(f"[dryrun] {arch} × {shape_name} "
                              f"mp={mp}: FAIL {type(e).__name__}: {e}")
                        if stop_on_error:
                            traceback.print_exc()
                            fh.write(json.dumps(rec) + "\n")
                            return failures
                    fh.write(json.dumps(rec) + "\n")
                    fh.flush()
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--multi-pod", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    modes = {"single": [False], "multi": [True],
             "both": [False, True]}[args.multi_pod]
    failures = run_cells(archs, shapes, modes, args.out,
                         stop_on_error=args.stop_on_error)
    print(f"[dryrun] done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
