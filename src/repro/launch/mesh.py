"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run driver
must set ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
the first JAX initialization, and any import-time device access would lock
the device count first.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh.

    Single pod: (data=16, model=16) — one v5e pod of 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
    the DCN dimension (batch-parallel only; no weight shards cross pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # AxisType only exists on newer jax; older versions default to Auto
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = ({"axis_types": (axis_type.Auto,) * len(axes)}
              if axis_type is not None else {})
    return jax.make_mesh(shape, axes, **kwargs)


def make_mesh_for(devices_or_count, model_axis: int = 1,
                  axis_names: Sequence[str] = ("data", "model")):
    """Best-effort mesh over an arbitrary device set (elastic re-mesh path).

    Used by the elastic resume logic: given however many devices survive,
    build a (data, model) mesh with the requested TP degree (clamped to what
    divides the device count).
    """
    import numpy as np
    if isinstance(devices_or_count, int):
        devices = jax.devices()[:devices_or_count]
    else:
        devices = list(devices_or_count)
    n = len(devices)
    tp = model_axis
    while n % tp:
        tp -= 1
    arr = np.array(devices).reshape(n // tp, tp)
    return jax.sharding.Mesh(arr, axis_names)
