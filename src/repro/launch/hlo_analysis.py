"""Roofline-term extraction from compiled (post-SPMD, post-fusion) HLO text.

``jax.stages.Compiled.cost_analysis()`` counts each ``while`` body **once**
(verified empirically: a 10-trip scan reports exactly 1/10 of the unrolled
FLOPs), which would make every scan-over-layers model's roofline wrong by a
factor of ``n_layers``. This module re-derives the three terms from
``compiled.as_text()`` with while-loop trip counts multiplied through
(XLA annotates ``backend_config={"known_trip_count":{"n":...}}``):

* **flops** — ``dot`` ops contribute 2·|result|·K (K = contracted extent);
  everything else contributes |result| per instruction (elementwise ≈ 1
  flop/element; negligible next to the dots but keeps small models honest).
* **bytes** — per top-level instruction: operand + result bytes (post-fusion
  HLO ≈ one HBM round-trip per fusion boundary). ``get-tuple-element``,
  ``tuple``, ``parameter``, ``constant`` and ``bitcast`` are free.
* **collective_bytes** — per-chip wire traffic with ring-algorithm factors:
  all-gather R·(n−1)/n, all-reduce 2·O·(n−1)/n, reduce-scatter O·(n−1)/n,
  all-to-all O·(n−1)/n, collective-permute R. ``n`` is the replica-group
  size parsed from the instruction; per-axis traffic is also split out so
  multi-pod (DCN) bytes can be separated from intra-pod (ICI) bytes.

Shapes in the compiled module are *per-device* shapes, so every number this
module emits is already per-chip — exactly what the roofline needs.

Validated against ``cost_analysis`` on unrolled graphs in
``tests/test_hlo_analysis.py``.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*([\w()]+?)\[([0-9,]*)\][^\s]*\s+"
    r"([\w\-]+)\((.*)$")
_TUPLE_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*\((.*?)\)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")


@dataclasses.dataclass
class Instr:
    name: str
    dtype: str
    shape: Tuple[int, ...]
    op: str
    rest: str

    @property
    def elements(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def result_bytes(self) -> int:
        return self.elements * _DTYPE_BYTES.get(self.dtype, 4)


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    # per (op, group_size) wire bytes — lets callers split ICI vs DCN
    collective_detail: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    def add(self, other: "Costs", times: float = 1.0) -> None:
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        self.collective_bytes += other.collective_bytes * times
        for k, v in other.collective_detail.items():
            self.collective_detail[k] = (self.collective_detail.get(k, 0.0)
                                         + v * times)


def _parse_shape(dtype: str, dims: str) -> Tuple[str, Tuple[int, ...]]:
    shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    return dtype, shape


class HloModule:
    def __init__(self, text: str) -> None:
        self.computations: Dict[str, List[Instr]] = {}
        self._parse(text)
        self._cost_cache: Dict[str, Costs] = {}

    # -- parsing -------------------------------------------------------------

    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" "):
                # computation header: non-indented, 'name (params) -> ty {'
                if line.endswith("{") and "->" in line:
                    m = _COMP_RE.match(line.strip())
                    if m and m.group(1) not in ("HloModule",):
                        current = m.group(1)
                        self.computations[current] = []
                continue
            if current is None:
                continue
            m = _INSTR_RE.match(line)
            if m:
                name, dtype, dims, op, rest = m.groups()
                dt, shape = _parse_shape(dtype, dims)
                self.computations[current].append(
                    Instr(name, dt, shape, op, rest))
                continue
            m = _TUPLE_INSTR_RE.match(line)
            if m:
                name, _inner, op, rest = m.groups()
                self.computations[current].append(
                    Instr(name, "opaque", (), op, rest))

    # -- helpers -------------------------------------------------------------

    def _shapes_of(self, comp: str) -> Dict[str, Instr]:
        return {i.name: i for i in self.computations.get(comp, [])}

    @staticmethod
    def _group_size(rest: str) -> int:
        m = _GROUPS_RE.search(rest)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(rest)
        if m:
            first = m.group(1).split("}")[0]
            return max(1, len([t for t in first.replace("{", "")
                              .split(",") if t.strip() != ""]))
        return 1

    def _operand_instrs(self, comp: str, rest: str) -> List[Instr]:
        names = _OPERAND_RE.findall(rest.split("),")[0])
        table = self._shapes_of(comp)
        return [table[n] for n in names if n in table]

    def _slice_only_params(self, comp: str) -> Dict[int, int]:
        """Parameters of ``comp`` consumed only via dynamic-slice: map
        param index → slice bytes (cached)."""
        key = f"__sliceonly__{comp}"
        if key in self._cost_cache:  # reuse cache dict as memo store
            return self._cost_cache[key]  # type: ignore[return-value]
        instrs = self.computations.get(comp, [])
        param_idx: Dict[str, int] = {}
        for ins in instrs:
            if ins.op == "parameter":
                m2 = re.match(r"(\d+)", ins.rest)
                if m2 is not None:
                    param_idx[ins.name] = int(m2.group(1))
        consumers: Dict[str, List[Instr]] = {}
        for ins in instrs:
            if ins.op == "parameter":
                continue
            for name in _OPERAND_RE.findall(ins.rest.split("),")[0]):
                if name in param_idx:
                    consumers.setdefault(name, []).append(ins)
        out: Dict[int, int] = {}
        for pname, idx in param_idx.items():
            cons = consumers.get(pname, [])
            if cons and all(c_.op in ("dynamic-slice", "bitcast")
                            for c_ in cons):
                ds = [c_ for c_ in cons if c_.op == "dynamic-slice"]
                if ds:
                    out[idx] = 2 * max(d.result_bytes for d in ds)
        self._cost_cache[key] = out  # type: ignore[assignment]
        return out

    # -- cost evaluation -----------------------------------------------------

    def cost_of(self, comp: str) -> Costs:
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        # memoize a zero first to break cycles defensively
        self._cost_cache[comp] = Costs()
        total = Costs()
        for ins in self.computations.get(comp, []):
            total.add(self._instr_cost(comp, ins))
        self._cost_cache[comp] = total
        return total

    def _instr_cost(self, comp: str, ins: Instr) -> Costs:
        c = Costs()
        op = ins.op
        if op in _FREE_OPS:
            return c
        if op == "while":
            body = _BODY_RE.search(ins.rest)
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            if body:
                c.add(self.cost_of(body.group(1)), times=trip)
            cond = _COND_RE.search(ins.rest)
            if cond:
                c.add(self.cost_of(cond.group(1)), times=trip)
            return c
        if op in ("call", "fusion"):
            callee_name = None
            callee = _CALLS_RE.search(ins.rest)
            if callee:
                callee_name = callee.group(1)
                inner = self.cost_of(callee_name)
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                for k, v in inner.collective_detail.items():
                    c.collective_detail[k] = (
                        c.collective_detail.get(k, 0.0) + v)
            # traffic at the fusion boundary; an operand consumed only by a
            # dynamic-slice inside the fusion is read slice-sized, not
            # buffer-sized (decode-cache reads would otherwise be charged
            # the full cache per layer)
            operands = self._operand_instrs(comp, ins.rest)
            sliced = (self._slice_only_params(callee_name)
                      if callee_name else {})
            total = ins.result_bytes
            for idx, o in enumerate(operands):
                total += sliced.get(idx, o.result_bytes)
            c.bytes += total
            return c
        if op == "conditional":
            # charge the most expensive branch
            branches = _OPERAND_RE.findall(ins.rest)
            best = Costs()
            for b in branches:
                if b in self.computations:
                    cb = self.cost_of(b)
                    if cb.flops >= best.flops:
                        best = cb
            c.add(best)
            return c

        operands = self._operand_instrs(comp, ins.rest)
        if op == "dynamic-slice":
            # reads only the slice region
            c.bytes += 2 * ins.result_bytes
            return c
        if op == "dynamic-update-slice":
            # in-place update: traffic = read update + write region (XLA
            # aliases the big operand; counting it would overstate HBM
            # traffic by the buffer/update ratio)
            upd = operands[1].result_bytes if len(operands) > 1 else \
                ins.result_bytes
            c.bytes += 2 * upd
            return c
        io_bytes = ins.result_bytes + sum(o.result_bytes for o in operands)
        c.bytes += io_bytes

        if op == "dot":
            k = 1
            mcon = _CONTRACT_RE.search(ins.rest)
            if mcon and operands:
                lhs = operands[0]
                for d in mcon.group(1).split(","):
                    if d != "" and int(d) < len(lhs.shape):
                        k *= lhs.shape[int(d)]
            c.flops += 2.0 * ins.elements * k
            return c
        if op == "convolution":
            # rough: 2 * output elements * (kernel elements / output feature)
            kern = operands[1].elements if len(operands) > 1 else 1
            out_f = ins.shape[-1] if ins.shape else 1
            c.flops += 2.0 * ins.elements * max(1, kern // max(1, out_f))
            return c
        if op in COLLECTIVES:
            n = self._group_size(ins.rest)
            factor = (n - 1) / n if n > 1 else 0.0
            operand_bytes = (operands[0].result_bytes if operands
                             else ins.result_bytes)
            if op == "all-gather":
                wire = ins.result_bytes * factor
            elif op == "all-reduce":
                wire = 2.0 * operand_bytes * factor
            elif op == "reduce-scatter":
                wire = operand_bytes * factor
            elif op == "all-to-all":
                wire = operand_bytes * factor
            else:  # collective-permute
                wire = float(ins.result_bytes)
            c.collective_bytes += wire
            key = f"{op}@{n}"
            c.collective_detail[key] = c.collective_detail.get(key, 0.0) + wire
            return c
        # default: elementwise-ish — 1 flop per output element
        c.flops += float(ins.elements)
        return c

    def entry(self) -> str:
        # the entry computation is conventionally named 'main...' or marked
        # ENTRY; we parsed in order, ENTRY computations keep their name
        for name in self.computations:
            if name.startswith("main"):
                return name
        return next(iter(self.computations))


def analyze(compiled_text: str) -> Dict[str, float]:
    """Full-module roofline terms (per device)."""
    mod = HloModule(compiled_text)
    costs = mod.cost_of(mod.entry())
    return {
        "flops": costs.flops,
        "bytes": costs.bytes,
        "collective_bytes": costs.collective_bytes,
        "collective_detail": dict(costs.collective_detail),
    }


def top_contributors(compiled_text: str, n: int = 20, key: str = "bytes"):
    """Largest single instructions by trip-weighted cost (hillclimb aid).

    Returns [(weighted_cost, computation, op, shape, trips)]. Trip weights
    are the product of enclosing while trip counts.
    """
    mod = HloModule(compiled_text)
    # compute trip multiplier per computation by walking while edges
    mult: Dict[str, float] = defaultdict(float)
    mult[mod.entry()] = 1.0
    frontier = [mod.entry()]
    seen = set()
    while frontier:
        comp = frontier.pop()
        if comp in seen:
            continue
        seen.add(comp)
        for ins in mod.computations.get(comp, []):
            callees = []
            trip = 1.0
            if ins.op == "while":
                mb, mt = _BODY_RE.search(ins.rest), _TRIP_RE.search(ins.rest)
                if mb:
                    callees = [mb.group(1)]
                    trip = float(mt.group(1)) if mt else 1.0
            elif ins.op in ("call", "fusion", "conditional"):
                mc = _CALLS_RE.search(ins.rest)
                if mc:
                    callees = [mc.group(1)]
            for cal in callees:
                mult[cal] = max(mult[cal], mult[comp] * trip)
                frontier.append(cal)
    rows = []
    for comp, instrs in mod.computations.items():
        w = mult.get(comp, 0.0)
        if w == 0.0:
            continue
        for ins in instrs:
            c = mod._instr_cost(comp, ins)
            val = getattr(c, key if key != "bytes" else "bytes")
            if val:
                rows.append((val * w, comp, ins.op, ins.shape, w, ins.name))
    rows.sort(reverse=True)
    return rows[:n]


# ---------------------------------------------------------------------------
# Roofline arithmetic (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


def roofline_terms(per_device: Dict[str, float]) -> Dict[str, float]:
    """Seconds per step for each roofline term (already per-chip numbers)."""
    t_compute = per_device["flops"] / PEAK_FLOPS
    t_memory = per_device["bytes"] / HBM_BW
    t_collective = per_device["collective_bytes"] / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_collective), key=lambda kv: kv[1])[0]
    return {
        "t_compute": t_compute,
        "t_memory": t_memory,
        "t_collective": t_collective,
        "dominant": dominant,
        "step_time_lower_bound": max(t_compute, t_memory, t_collective),
    }
