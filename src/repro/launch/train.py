"""Training driver: EnTK-managed, checkpointed, elastic LM training.

The run is expressed as an EnTK pipeline (the paper's PST model):

    Pipeline[ Stage(init) → Stage(chunk_0) → … → Stage(chunk_k) → Stage(eval) ]

Each *chunk task* trains ``steps_per_chunk`` steps from the latest
checkpoint and writes a new one. Failure anywhere (task crash, injected
fault, RTS death) is handled by the toolkit's resubmission/restart path,
and the resubmitted chunk resumes from the checkpoint — completed work is
never repeated, the paper's fault-tolerance contract carried through to
the training substrate.

Also usable directly (``python -m repro.launch.train --arch <id> --smoke``)
without EnTK for quick runs.
"""

from __future__ import annotations

import argparse
import functools
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import AppManager, Pipeline, Stage, Task, register_executable
from repro.rts.base import ResourceDescription
from repro.rts.jax_rts import JaxRTS
from repro.checkpoint import CheckpointManager
from repro.data import make_stream, Prefetcher
from repro.models import steps as steps_mod
from repro.models.config import get_config
from repro.optim.adamw import AdamWConfig
from repro.optim import compression

_SESSIONS: Dict[str, "TrainSession"] = {}


class TrainSession:
    """Process-cached jitted state for one training run."""

    def __init__(self, arch: str, smoke: bool, seq_len: int,
                 global_batch: int, ckpt_dir: str,
                 grad_compression: Optional[str] = None,
                 lr: float = 3e-4) -> None:
        self.cfg = get_config(arch, smoke=smoke)
        self.ckpt = CheckpointManager(ckpt_dir)
        self.stream = make_stream(self.cfg, seq_len, global_batch)
        opt = AdamWConfig(lr=lr, warmup_steps=20, total_steps=100000)
        self.compression = grad_compression
        self._step_fn = jax.jit(steps_mod.make_train_step(self.cfg, opt))
        self.state = None
        self.step = 0
        self.error_state = None

    def restore_or_init(self) -> int:
        latest = self.ckpt.latest()
        if latest is None:
            self.state = steps_mod.init_train_state(
                self.cfg, jax.random.PRNGKey(0))
            self.step = 0
        elif self.state is None or self.step != latest:
            abstract = steps_mod.abstract_train_state(self.cfg)
            self.state, self.step, _ = self.ckpt.restore(abstract)
        return self.step

    def run_steps(self, n: int, save: bool = True) -> Dict[str, float]:
        self.restore_or_init()
        if self.compression == "int8" and self.error_state is None:
            self.error_state = compression.init_error(
                self.state["params"])
        pf = Prefetcher(self.stream, start_step=self.step)
        losses = []
        try:
            for _ in range(n):
                _step_idx, batch = pf.next()
                batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                self.state, metrics = self._step_fn(self.state, batch)
                losses.append(float(metrics["loss"]))
                self.step += 1
        finally:
            pf.stop()
        if save:
            self.ckpt.save_async(self.step, self.state,
                                 extra={"loss": losses[-1]})
            self.ckpt.wait()
        return {"step": self.step, "loss_first": losses[0],
                "loss_last": losses[-1],
                "loss_mean": float(np.mean(losses))}


def get_session(key: str, **kwargs: Any) -> TrainSession:
    if key not in _SESSIONS:
        _SESSIONS[key] = TrainSession(**kwargs)
    return _SESSIONS[key]


def train_chunk(arch: str, smoke: bool, seq_len: int, global_batch: int,
                ckpt_dir: str, steps: int,
                grad_compression: Optional[str] = None,
                lr: float = 3e-4, fail_once_at: Optional[int] = None
                ) -> Dict[str, float]:
    """EnTK task executable: train ``steps`` steps from the latest ckpt.

    ``fail_once_at``: testing hook — raise once when the global step passes
    this value (exercises the resubmission path; the retry resumes from the
    checkpoint).
    """
    sess = get_session(ckpt_dir, arch=arch, smoke=smoke, seq_len=seq_len,
                       global_batch=global_batch, ckpt_dir=ckpt_dir,
                       grad_compression=grad_compression, lr=lr)
    start = sess.restore_or_init()
    if fail_once_at is not None and start <= fail_once_at:
        flag = f"{ckpt_dir}/.failed_once"
        import os
        if not os.path.exists(flag):
            open(flag, "w").write("x")
            raise RuntimeError(
                f"injected training fault at step {start}")
    return sess.run_steps(steps)


register_executable("train_chunk", train_chunk)


def build_training_pipeline(arch: str, *, smoke: bool, seq_len: int,
                            global_batch: int, ckpt_dir: str,
                            total_steps: int, steps_per_chunk: int,
                            max_retries: int = 2,
                            fail_once_at: Optional[int] = None) -> Pipeline:
    pipe = Pipeline(f"train-{arch}")
    n_chunks = -(-total_steps // steps_per_chunk)
    for c in range(n_chunks):
        st = Stage(f"chunk{c}")
        steps = min(steps_per_chunk, total_steps - c * steps_per_chunk)
        st.add_tasks(Task(
            name=f"{arch}-chunk{c}",
            executable="reg://train_chunk",
            kwargs={"arch": arch, "smoke": smoke, "seq_len": seq_len,
                    "global_batch": global_batch, "ckpt_dir": ckpt_dir,
                    "steps": steps,
                    "fail_once_at": fail_once_at},
            max_retries=max_retries,
            duration_hint=steps * 2.0,
        ))
        pipe.add_stages(st)
    return pipe


def run_managed(arch: str, *, smoke: bool = True, seq_len: int = 128,
                global_batch: int = 8, total_steps: int = 20,
                steps_per_chunk: int = 5, ckpt_dir: str = "/tmp/entk-train",
                fail_once_at: Optional[int] = None,
                timeout: float = 3600.0) -> AppManager:
    """Run a training pipeline under the full EnTK stack; returns the
    AppManager (overheads in ``.prof``, states in ``.state_table``)."""
    amgr = AppManager(
        resources=ResourceDescription(slots=1),
        rts_factory=JaxRTS,
        journal_path=f"{ckpt_dir}/journal.jsonl",
    )
    amgr.workflow = [build_training_pipeline(
        arch, smoke=smoke, seq_len=seq_len, global_batch=global_batch,
        ckpt_dir=ckpt_dir, total_steps=total_steps,
        steps_per_chunk=steps_per_chunk, fail_once_at=fail_once_at)]
    amgr.run(timeout=timeout)
    return amgr


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--steps-per-chunk", type=int, default=5)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/entk-train")
    ap.add_argument("--managed", action="store_true",
                    help="run through the EnTK stack (default: direct loop)")
    args = ap.parse_args()

    if args.managed:
        t0 = time.time()
        amgr = run_managed(args.arch, smoke=args.smoke,
                           seq_len=args.seq_len, global_batch=args.batch,
                           total_steps=args.steps,
                           steps_per_chunk=args.steps_per_chunk,
                           ckpt_dir=args.ckpt_dir)
        print(f"managed run done in {time.time()-t0:.1f}s; "
              f"all tasks DONE: {amgr.all_done}")
        for cat, secs in sorted(amgr.prof.totals().items()):
            print(f"  {cat}: {secs:.3f}s")
    else:
        sess = get_session(args.ckpt_dir, arch=args.arch, smoke=args.smoke,
                           seq_len=args.seq_len, global_batch=args.batch,
                           ckpt_dir=args.ckpt_dir)
        out = sess.run_steps(args.steps)
        print(out)


if __name__ == "__main__":
    main()
