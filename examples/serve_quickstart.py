"""Serving quickstart: embed the ensemble service in your own process.

No socket, no daemon — the :class:`~repro.serve.client.InProcessClient`
speaks the same protocol straight into the service, which is the simplest
way to give one application many concurrently-running workflows with
admission control and fair share.

    PYTHONPATH=src python examples/serve_quickstart.py
"""

from repro.core.pst import register_executable
from repro.fusion import fusable
from repro.serve import (AdmissionController, EnsembleService,
                         InProcessClient, TenantQuota)


@fusable()
def square(x):
    import jax.numpy as jnp
    v = jnp.asarray(x, jnp.float32)
    return v * v


register_executable("quickstart_square", square)


def main() -> None:
    admission = AdmissionController(
        default_quota=TenantQuota(max_in_flight_members=256, max_active=4))
    service = EnsembleService(admission=admission,
                              serve_hold_s=0.1).start()
    try:
        client = InProcessClient(service)
        print(client.hello())

        handles = {
            tenant: client.submit(
                "reg://quickstart_square",
                [{"x": float(base + i)} for i in range(8)],
                tenant=tenant, name="sq")
            for tenant, base in [("research", 0), ("prod", 100)]}

        for tenant, handle in handles.items():
            client.wait(handle, timeout=120)
            results = client.result(handle)
            print(f"{tenant}: sq-0={results['sq-0']} sq-7={results['sq-7']}")

        stats = client.stats()
        print(f"cross-tenant carriers: "
              f"{stats['fusion'].get('cross_tenant_carriers', 0)}")
        print(f"admission: {stats['admission']}")

        # the `metrics` verb: per-tenant telemetry (queue-wait quantiles
        # inside the serve hold window, carrier sharing, completions)
        metrics = client.metrics()
        for tenant, m in sorted(metrics["tenants"].items()):
            wait = m.get("queue_wait") or {}
            p50 = wait.get("p50")
            print(f"metrics[{tenant}]: members={m.get('members', 0)} "
                  f"shared_carriers={m.get('shared_carriers', 0)} "
                  f"completions={m.get('completions', 0)} "
                  f"queue_wait_p50="
                  f"{f'{p50 * 1e3:.1f}ms' if p50 is not None else 'n/a'}")
    finally:
        service.stop()


if __name__ == "__main__":
    main()
