"""End-to-end driver: train a ~100M-parameter LM under full EnTK management.

The run is a PST pipeline of train-chunk tasks (each trains N steps from
the latest checkpoint and writes a new one); the toolkit provides fault
tolerance — ``--inject-fault`` makes one chunk crash mid-run, EnTK
resubmits it, and the retry resumes from the checkpoint without repeating
completed work.

Default is a quick demo (60 steps). The full few-hundred-step run of the
assignment is:

    PYTHONPATH=src python examples/train_ensemble.py --steps 300

~100M config: d_model=640, 10 layers, vocab 32000 (≈106M params).
"""

import argparse
import os
import shutil
import time

from repro.models.config import ModelConfig, register_arch


def _lm100m() -> ModelConfig:
    return ModelConfig(
        name="lm100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=5, d_ff=2560, vocab_size=32000,
        rope_variant="standard")


register_arch("lm100m", _lm100m, _lm100m)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--steps-per-chunk", type=int, default=20)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/entk-train-100m")
    ap.add_argument("--inject-fault", action="store_true")
    ap.add_argument("--fresh", action="store_true",
                    help="delete the checkpoint dir first")
    args = ap.parse_args()

    if args.fresh and os.path.isdir(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    from repro.launch.train import run_managed, get_session
    cfg = _lm100m()
    print(f"model: {cfg.name} ≈{cfg.n_params()/1e6:.0f}M params")
    print(f"training {args.steps} steps in chunks of "
          f"{args.steps_per_chunk} (seq {args.seq_len}, batch {args.batch})")

    t0 = time.time()
    amgr = run_managed(
        "lm100m", smoke=False, seq_len=args.seq_len,
        global_batch=args.batch, total_steps=args.steps,
        steps_per_chunk=args.steps_per_chunk, ckpt_dir=args.ckpt_dir,
        fail_once_at=(args.steps_per_chunk if args.inject_fault else None),
        timeout=24 * 3600)
    elapsed = time.time() - t0

    print(f"\nall chunks DONE: {amgr.all_done}  ({elapsed:.0f} s)")
    results = [t.result for p in amgr.workflow for s in p.stages
               for t in s.tasks if t.result]
    for r in results:
        print(f"  step {r['step']:4d}: loss {r['loss_last']:.4f}")
    retries = sum(t.retries for p in amgr.workflow for s in p.stages
                  for t in s.tasks)
    if args.inject_fault:
        print(f"injected fault recovered via resubmission "
              f"(total retries: {retries})")
    first = results[0]["loss_first"] if results else float("nan")
    last = results[-1]["loss_last"] if results else float("nan")
    print(f"loss: {first:.3f} → {last:.3f}")
    tok_s = args.steps * args.seq_len * args.batch / elapsed
    print(f"throughput ≈ {tok_s:,.0f} tokens/s on this host")


if __name__ == "__main__":
    main()
