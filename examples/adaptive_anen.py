"""Adaptive Analog Ensemble (paper use case §III-B, Fig. 11).

Runs the AUA (adaptive) and random-placement analog searches, described as
``api.repeat_until`` loops over ``api.ensemble`` rounds — the compiler
lowers them onto EnTK's runtime stage-appending (the paper's
branching-as-decision-task) — and compares error convergence.

    pip install -e .   (or: PYTHONPATH=src)
    python examples/adaptive_anen.py [--repeats 3]
"""

import argparse

import numpy as np

from repro.apps.anen.workflow import run_adaptive, run_random


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--grid", type=int, default=64)
    ap.add_argument("--per-iter", type=int, default=40)
    ap.add_argument("--iters", type=int, default=4)
    args = ap.parse_args()

    kw = dict(ny=args.grid, nx=args.grid, per_iter=args.per_iter,
              max_iters=args.iters, n_hist=100)
    aua_final, rnd_final = [], []
    for seed in range(args.repeats):
        a = run_adaptive(seed=seed, **kw)
        r = run_random(seed=seed, **kw)
        # the adaptive loop must actually have adapted: every round past the
        # first was appended at runtime by the repeat_until machinery
        assert a["all_done"] and r["all_done"], (a, r)
        assert a["rounds"] >= 2, f"no adaptive round ran: {a}"
        aua_final.append(a["final_rmse"])
        rnd_final.append(r["final_rmse"])
        print(f"seed {seed}:")
        print(f"  AUA    errors per iteration: "
              f"{[round(e, 4) for e in a['errors']]}")
        print(f"  random errors per iteration: "
              f"{[round(e, 4) for e in r['errors']]}")

    print(f"\nover {args.repeats} repeats "
          f"({args.per_iter * args.iters} locations of "
          f"{args.grid * args.grid} pixels):")
    print(f"  AUA    median RMSE: {np.median(aua_final):.4f}")
    print(f"  random median RMSE: {np.median(rnd_final):.4f}")
    wins = sum(a < r for a, r in zip(aua_final, rnd_final))
    print(f"  AUA wins {wins}/{args.repeats} "
          "(cf. paper Fig. 11d: adaptive converges faster)")


if __name__ == "__main__":
    main()
