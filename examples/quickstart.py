"""Quickstart: the PST model in 30 lines.

Two pipelines run concurrently; stages inside each run sequentially; the 8
tasks of every stage run concurrently on a 4-slot pilot. One flaky task
fails twice and is resubmitted automatically.

    pip install -e .   (or: PYTHONPATH=src)
    python examples/quickstart.py
"""

from repro.core import AppManager, Pipeline, Stage, Task
from repro.rts.base import ResourceDescription
from repro.rts.local import LocalRTS

attempts = {}


def flaky_injector(task):
    """Make 'flaky' fail on its first two attempts."""
    attempts[task.name] = attempts.get(task.name, 0) + 1
    return task.name == "flaky" and attempts[task.name] <= 2


def main() -> None:
    pipelines = []
    for p in range(2):
        pipe = Pipeline(f"pipe{p}")
        for s in range(2):
            stage = Stage(f"stage{s}")
            stage.add_tasks([
                Task(name=f"p{p}s{s}t{t}", executable="sleep://0.05")
                for t in range(8)])
            pipe.add_stages(stage)
        pipelines.append(pipe)
    # one deliberately flaky task with a retry budget
    pipelines[0].stages[0].add_tasks(
        Task(name="flaky", executable="sleep://0.05", max_retries=3))

    amgr = AppManager(
        resources=ResourceDescription(slots=4),
        rts_factory=lambda: LocalRTS(fault_injector=flaky_injector))
    amgr.workflow = pipelines
    overheads = amgr.run()

    print(f"all tasks DONE: {amgr.all_done}")
    print(f"flaky task attempts: {attempts.get('flaky')}")
    print("overhead decomposition (paper Fig. 7 categories):")
    for cat, secs in sorted(overheads.items()):
        print(f"  {cat:18s} {secs:8.4f} s")


if __name__ == "__main__":
    main()
