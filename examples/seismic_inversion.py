"""Seismic FWI mini-campaign (paper use case §III-A) under EnTK.

1. "Observe": forward-simulate an ensemble of earthquakes on the true
   velocity model (EnTK stage of concurrent forward tasks, with injected
   failures + automatic resubmission — the Fig. 10 scenario).
2. Invert: a few adjoint-gradient iterations on a smooth starting model,
   each iteration an EnTK stage of per-event gradient tasks whose results
   are summed into a model update.

    pip install -e .   (or: PYTHONPATH=src)
    python examples/seismic_inversion.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import AppManager, Pipeline, Stage, Task, \
    register_executable
from repro.rts.base import ResourceDescription
from repro.rts.local import LocalRTS
from repro.apps.seismic.solver import (SeismicConfig, forward_simulation,
                                       make_velocity_model,
                                       misfit_and_grad)

CFG = SeismicConfig(nx=64, nz=64, nt=140, n_receivers=16)
_STATE = {}


def observe_task(source_x: int):
    vel = _STATE["v_true"]
    seis = forward_simulation(vel, source_x, CFG)
    return {"source_x": source_x, "seis": np.asarray(seis).tolist()}


def gradient_task(source_x: int):
    v = _STATE["v_current"]
    obs = _STATE["observed"][source_x]
    m, g = misfit_and_grad(v, obs, source_x, CFG)
    return {"misfit": float(m), "grad": np.asarray(g).tolist()}


register_executable("fwi_observe", observe_task)
register_executable("fwi_gradient", gradient_task)


def run_stage(tasks, slots=4, failure_rate=0.0):
    rng = np.random.default_rng(0)
    amgr = AppManager(
        resources=ResourceDescription(slots=slots),
        rts_factory=lambda: LocalRTS(
            fault_injector=lambda t: rng.random() < failure_rate))
    pipe = Pipeline("fwi")
    st = Stage()
    st.add_tasks(tasks)
    pipe.add_stages(st)
    amgr.workflow = [pipe]
    amgr.run(timeout=1800)
    assert amgr.all_done, "stage failed"
    return [t.result for t in st.tasks]


def main() -> None:
    sources = [12, 24, 36, 48]
    _STATE["v_true"] = make_velocity_model(CFG, "true")

    print("stage 1: observing (forward ensemble, 30% injected failures)")
    results = run_stage(
        [Task(name=f"obs{sx}", executable="reg://fwi_observe",
              kwargs={"source_x": sx}, max_retries=5) for sx in sources],
        failure_rate=0.3)
    _STATE["observed"] = {
        r["source_x"]: jnp.asarray(r["seis"], jnp.float32) for r in results}

    v = make_velocity_model(CFG, "background")
    print("stage 2: adjoint inversion iterations (backtracking steps)")
    eps = 4.0  # m/s perturbation along the normalized gradient
    prev = None
    for it in range(4):
        _STATE["v_current"] = v
        grads = run_stage(
            [Task(name=f"grad{it}-{sx}", executable="reg://fwi_gradient",
                  kwargs={"source_x": sx}, max_retries=2)
             for sx in sources])
        total_misfit = sum(g["misfit"] for g in grads)
        if prev is not None and total_misfit > prev:
            eps *= 0.3  # overshoot: backtrack
        prev = total_misfit
        g_sum = jnp.asarray(
            np.sum([np.asarray(g["grad"]) for g in grads], axis=0),
            jnp.float32)
        g_norm = g_sum / max(1e-12, float(jnp.abs(g_sum).max()))
        v = v - eps * g_norm
        err = float(jnp.abs(v - _STATE["v_true"]).mean())
        print(f"  iter {it}: misfit {total_misfit:10.5f}  "
              f"model error {err:8.3f} m/s  (step {eps:.2f} m/s)")
    print("done — misfit decreased via EnTK-managed adjoint ensembles")


if __name__ == "__main__":
    main()
