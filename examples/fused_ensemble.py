"""The fusion engine in one tour: one description, two execution modes.

A homogeneous ensemble of 256 members (same kernel, different arguments)
runs twice on the same JaxRTS device pool:

* ``fuse=False`` — the classic toolkit path: one task per member, one
  Python thread per task, one JAX dispatch per task;
* ``fuse=True`` (the default) — members tagged with a fusion group key at
  compile time are packed into micro-batches and executed as a handful of
  vectorized device dispatches, while completions, failures and journal
  records stay per-member.

The values are verified identical member-by-member; only the wall clock
changes.

    pip install -e .   (or: PYTHONPATH=src)
    python examples/fused_ensemble.py
"""

import time

import numpy as np

from repro import api
from repro.fusion import fusable
from repro.rts.base import ResourceDescription
from repro.rts.jax_rts import JaxRTS


@fusable(static_argnames=("steps",))
def trajectory_energy(x0: float, drag: float, steps: int = 64):
    """One ensemble member: a toy damped-oscillator rollout."""
    import jax.numpy as jnp
    x = jnp.float32(x0)
    v = jnp.float32(1.0)
    for _ in range(steps):
        v = v - 0.05 * x - drag * v
        x = x + 0.05 * v
    return x * x + v * v


def run(fuse: bool):
    ens = api.ensemble(
        trajectory_energy,
        over=[{"x0": i / 256.0, "drag": 0.02 + (i % 4) * 0.01,
               "steps": 64} for i in range(256)],
        name="traj", fuse=fuse)
    holder = {}

    def factory():
        holder["rts"] = JaxRTS(slot_oversubscribe=4)
        return holder["rts"]

    t0 = time.time()
    result = api.run(ens, resources=ResourceDescription(slots=4),
                     rts_factory=factory, timeout=300)
    elapsed = time.time() - t0
    assert result.all_done, "ensemble did not complete"
    values = [float(np.asarray(s.out.result())) for s in ens.specs]
    stats = holder["rts"].fusion_stats
    result.close()
    return elapsed, values, stats


def main() -> None:
    t_scalar, v_scalar, _ = run(fuse=False)
    t_fused, v_fused, stats = run(fuse=True)
    print(f"scalar : 256 members in {t_scalar:.2f}s "
          f"({256 / t_scalar:.0f} tasks/s)")
    print(f"fused  : 256 members in {t_fused:.2f}s "
          f"({256 / t_fused:.0f} tasks/s) — "
          f"{stats['dispatches']} device dispatches")
    print(f"speedup: {t_scalar / t_fused:.1f}x")
    drift = max(abs(a - b) for a, b in zip(v_scalar, v_fused))
    print(f"max member drift: {drift:.2e}")
    if drift > 1e-5:
        raise SystemExit("fused values drifted from scalar values")
    print("fused and scalar runs produced identical member values")


if __name__ == "__main__":
    main()
