"""Batched serving under EnTK: prefill + greedy decode per request batch.

Each batch of prompts is one EnTK task (failed batches are resubmitted by
the toolkit). Uses a reduced config of the selected architecture.

    PYTHONPATH=src python examples/serve_batch.py --arch starcoder2-7b
"""

import argparse
import time

from repro.launch.serve import run_managed
from repro.models.config import get_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.embedding_inputs:
        print(f"{args.arch} takes embedding inputs (modality stub); "
              "switching to chatglm3-6b for the token-level demo")
        args.arch = "chatglm3-6b"

    t0 = time.time()
    amgr = run_managed(args.arch, n_batches=args.batches,
                       batch_size=args.batch_size,
                       max_new_tokens=args.new_tokens)
    elapsed = time.time() - t0
    tasks = [t for p in amgr.workflow for s in p.stages for t in s.tasks]
    n_tokens = sum(len(t.result) * args.new_tokens
                   for t in tasks if t.result)
    print(f"served {len(tasks)} batches, all DONE: {amgr.all_done}")
    print(f"generated {n_tokens} tokens in {elapsed:.1f} s "
          f"({n_tokens / elapsed:.1f} tok/s on this host)")
    for t in tasks[:2]:
        print(f"  {t.name}: first sequence -> {t.result[0]}")


if __name__ == "__main__":
    main()
