"""Multi-tenant serving demo: two clients, one daemon, shared carriers.

Starts an :class:`~repro.serve.service.EnsembleService` with its socket
front-end, then drives it from TWO concurrent tenants submitting sweeps of
the SAME kernel. The fusion group key excludes the workflow namespace, so
the service's continuous-batching window packs both tenants' members into
shared carriers — watch ``cross_tenant_carriers`` and the per-tenant
``shared_dispatches`` in the printed stats — while every result routes back
to its own tenant's namespace.

    PYTHONPATH=src python examples/serve_batch.py
"""

import argparse
import threading

from repro.core.pst import register_executable
from repro.fusion import fusable
from repro.serve import EnsembleService, ServiceDaemon, SocketClient


@fusable()
def saxpy(a, x):
    import jax.numpy as jnp
    return jnp.asarray(a, jnp.float32) * jnp.asarray(x, jnp.float32) + 1.0


register_executable("serve_demo_kernel", saxpy)


def run_tenant(port: int, tenant: str, base: float, n: int, out: dict) -> None:
    with SocketClient("127.0.0.1", port) as client:
        handle = client.submit(
            "reg://serve_demo_kernel",
            [{"a": 2.0, "x": base + i} for i in range(n)],
            tenant=tenant, name="sweep")
        client.wait(handle, timeout=120)
        out[tenant] = client.result(handle)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--members", type=int, default=16,
                    help="sweep width per tenant")
    ap.add_argument("--hold-ms", type=float, default=250.0,
                    help="continuous-batching window")
    args = ap.parse_args()

    service = EnsembleService(serve_hold_s=args.hold_ms / 1000.0).start()
    daemon = ServiceDaemon(service, port=0).start()
    print(f"daemon listening on 127.0.0.1:{daemon.port}")

    results: dict = {}
    tenants = [("alice", 0.0), ("bob", 1000.0)]
    threads = [threading.Thread(target=run_tenant,
                                args=(daemon.port, t, base,
                                      args.members, results))
               for t, base in tenants]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        stats = service.stats()
        fusion = stats["fusion"]
        print(f"\ncarriers shared across tenants: "
              f"{fusion.get('cross_tenant_carriers', 0)} "
              f"(of {fusion.get('dispatches', 0)} dispatches)")
        for tenant, base in tenants:
            ts = stats["tenants"].get(tenant, {})
            print(f"  {tenant}: members={ts.get('members', 0)} "
                  f"shared_dispatches={ts.get('shared_dispatches', 0)} "
                  f"completions={ts.get('completions', 0)}")
            sample = results[tenant]["sweep-0"]
            expect = 2.0 * base + 1.0
            assert abs(float(sample) - expect) < 1e-5, (tenant, sample)
            print(f"  {tenant}: sweep-0 = {float(sample):.1f}  (isolated ok)")
        assert fusion.get("cross_tenant_carriers", 0) >= 1, \
            "expected at least one carrier mixing both tenants"
        print("\nboth tenants served from shared carriers, "
              "results fully isolated")
    finally:
        daemon.stop()
        service.stop()


if __name__ == "__main__":
    main()
