"""The declarative ensemble API in one tour: futures, combinators, adaptivity.

A parameter sweep feeds a reduction; the reduction's value steers a branch;
an adaptive repeat_until loop refines until a tolerance is met. The whole
description compiles onto PST (``api.compile``) and runs on the unchanged
event-driven core — swap ``resources=`` for a list of descriptions and the
same description executes on a federated multi-pilot fleet.

    pip install -e .   (or: PYTHONPATH=src)
    python examples/declarative_ensemble.py
"""

from repro import api
from repro.rts.base import ResourceDescription


def simulate(x, damping):
    """A toy 'simulation': one member of the sweep."""
    return damping * x * x


def statistics(values):
    """Reduction over the whole ensemble's outputs."""
    return {"n": len(values), "mean": sum(values) / len(values),
            "max": max(values)}


def refine(lo, hi, target):
    """One bisection step toward sqrt(target)."""
    mid = (lo + hi) / 2.0
    if mid * mid < target:
        return {"lo": mid, "hi": hi, "target": target}
    return {"lo": lo, "hi": mid, "target": target}


def main() -> None:
    # 1. ensemble + gather: 12 simulations -> one statistics task.
    sims = api.ensemble(simulate,
                        over=api.sweep(x=range(6), damping=[0.5, 1.0]),
                        name="sim")
    stats = api.gather(sims, statistics, name="stats")

    # 2. branch: only spawn the expensive follow-up when the mean is large.
    followup = api.branch(
        lambda ctx: ctx.value["mean"] > 4.0,
        then=lambda ctx: api.task(simulate,
                                  kwargs={"x": ctx.value["max"],
                                          "damping": 1.0},
                                  name="followup-sim"),
        orelse=None, after=stats, name="followup")

    # 3. repeat_until: bisect sqrt(2) until the bracket is tight. Rounds are
    #    appended at runtime; results flow between rounds as futures.
    def next_round(ctx):
        state = ({"lo": 1.0, "hi": 2.0, "target": 2.0}
                 if ctx.results is None else ctx.results[0])
        return api.task(refine, kwargs=state, name=f"bisect-r{ctx.round}")

    bisect = api.repeat_until(
        lambda ctx: ctx.results[0]["hi"] - ctx.results[0]["lo"] < 1e-3,
        next_round, max_rounds=20, name="bisect")

    result = api.run(followup, bisect,
                     resources=ResourceDescription(slots=4),
                     name="declarative-demo", timeout=300)

    s = stats.out.result()
    print(f"ensemble of {s['n']}: mean={s['mean']:.2f} max={s['max']:.1f}")
    print(f"branch value: {followup.out.result()}")
    bracket = bisect.out.result()[0]
    mid = (bracket["lo"] + bracket["hi"]) / 2
    print(f"bisect converged: sqrt(2) ~= {mid:.4f} "
          f"(bracket width {bracket['hi'] - bracket['lo']:.2e})")
    print(f"all tasks DONE: {result.all_done}")

    assert result.all_done
    assert s["n"] == 12 and abs(s["max"] - 25.0) < 1e-9
    assert abs(mid - 2 ** 0.5) < 1e-3
    assert followup.out.result() == [625.0]  # mean 6.88 > 4 -> arm ran
    print("OK")


if __name__ == "__main__":
    main()
