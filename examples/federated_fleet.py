"""Federated fleet: one workflow across heterogeneous pilots, with failover.

A mixed fleet — a CPU pool (LocalRTS) plus a device pool (JaxRTS over the
host's JAX devices) plus a spare CPU pool — serves one ensemble:

* preprocessing tasks are free to run anywhere (least-loaded spill),
* "train" tasks are pinned to the device pool with ``Task(backend="devices")``
  (hard affinity: a device-shaped task must never land on a CPU pilot),
* mid-run, the spare pool's pilot is killed: its in-flight tasks are
  re-journaled as FAILED-with-requeue (no retry budget consumed) and finish
  on the surviving members — zero lost completions.

    pip install -e .   (or: PYTHONPATH=src)
    python examples/federated_fleet.py
"""

import threading
import time

from repro.core import AppManager, Pipeline, Stage, Task
from repro.core.pst import register_executable
from repro.rts.base import ResourceDescription
from repro.rts.jax_rts import JaxRTS
from repro.rts.local import LocalRTS


def train_step(shard, devices=None):
    """A stand-in jitted step; the JaxRTS leases it real device objects."""
    time.sleep(0.05)
    return {"shard": shard, "devices": [str(d) for d in (devices or [])]}


def main() -> None:
    register_executable("train_step", train_step)

    # --- the fleet: three differently-shaped pilots --------------------- #
    resources = [
        ResourceDescription(slots=4, extra={"name": "cpu"}),
        ResourceDescription(slots=2, extra={"name": "devices"}),
        ResourceDescription(slots=2, extra={"name": "spare"}),
    ]
    factories = [
        LocalRTS,
        lambda: JaxRTS(slot_oversubscribe=2),  # host devices, 2× logical
        LocalRTS,
    ]

    # --- the workflow: spill-anywhere prep, device-pinned training ------ #
    pipe = Pipeline("fleet")
    prep = Stage("prep")
    prep.add_tasks([Task(name=f"prep-{i}", executable="sleep://0.2")
                    for i in range(16)])
    train = Stage("train")
    train.add_tasks([Task(name=f"train-{i}", executable="reg://train_step",
                          args=(i,), backend="devices")
                     for i in range(4)])
    pipe.add_stages([prep, train])

    amgr = AppManager(resources=resources, rts_factory=factories,
                      heartbeat_interval=0.1)
    amgr.workflow = [pipe]

    # --- kill the spare pool mid-run: failover, not failure ------------- #
    def kill_spare():
        # wait for the fleet to be live (JAX device init can take a while)
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            fed = amgr.emgr.rts if amgr.emgr is not None else None
            if (fed is not None and getattr(fed, "_started", False)
                    and fed.members[2].rts is not None):
                break
            time.sleep(0.02)
        else:
            return
        time.sleep(0.25)
        fed.members[2].rts.simulate_dead = True
        print("!! spare pool pilot killed mid-run")

    threading.Thread(target=kill_spare, daemon=True).start()

    amgr.run(timeout=120)
    fed = amgr.emgr.rts

    print(f"all tasks DONE: {amgr.all_done}")
    print(f"fleet: {[(m.name, m.granted) for m in fed.members]}")
    print(f"members lost: {fed.members_lost}, "
          f"tasks failed over: {fed.pilot_lost_requeues}, "
          f"re-admitted: {fed.members_readmitted}")
    for m in fed.members:
        print(f"  {m.name:8s} executed {m.tasks_run} task attempts")
    done = [t for t in pipe.stages[1].tasks]
    print(f"train results on devices: "
          f"{[t.result['devices'] for t in done if t.result]}")


if __name__ == "__main__":
    main()
